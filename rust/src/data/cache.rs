//! Monolithic binary on-disk dataset format (write once, memory-load fast).
//!
//! Examples and benches cache generated corpora so repeated runs skip
//! synthesis. Format (little-endian):
//!
//! ```text
//! magic   8 bytes  "CRSTDS1\0"
//! n       u64      examples
//! d       u64      feature dim
//! classes u64
//! x       n*d f32
//! y       n   i32
//! difficulty n f32
//! is_noisy   n u8
//! cluster    n u32
//! ```
//!
//! This format is always loaded fully resident, so it keeps a sanity cap
//! on `n*d`; corpora beyond it belong in the sharded format
//! ([`super::shard`], written by `crest pack`), which has no cap and
//! backs the mmap store.

use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::data::dataset::Dataset;
use crate::tensor::MatF32;
use crate::util::artifact_io::{self, ArtifactError, READ_STRICT};
use crate::util::faults::Site;

const MAGIC: &[u8; 8] = b"CRSTDS1\0";

/// Monolithic caches cap the resident payload at 2^31 f32 elements
/// (8 GiB of features); larger corpora must use the sharded format.
pub const MAX_RESIDENT_ELEMS: u64 = 1 << 31;

/// Total file size implied by the header dims.
fn expected_len(n: u64, d: u64) -> Option<u64> {
    // header + features + y + difficulty + is_noisy + cluster
    let feat = n.checked_mul(d)?.checked_mul(4)?;
    Some(8 + 24 + feat + n * 4 + n * 4 + n + n * 4)
}

/// Write a dataset to the binary cache format at `path`.
///
/// Features stream out block-at-a-time through the dataset's store, so a
/// disk-backed dataset can be re-cached without materializing it (the
/// *result* must still fit the resident cap to be loadable).
pub fn save(ds: &Dataset, path: &Path) -> Result<()> {
    let f = artifact_io::create(Site::CacheStore, path)
        .with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    for v in [ds.n() as u64, ds.d() as u64, ds.classes as u64] {
        w.write_all(&v.to_le_bytes())?;
    }
    let (n, d) = (ds.n(), ds.d());
    let block = 4096.min(n.max(1));
    let mut buf = vec![0.0f32; block * d];
    let mut start = 0;
    while start < n {
        let rows = block.min(n - start);
        ds.read_block(start, rows, &mut buf[..rows * d]);
        for &f in &buf[..rows * d] {
            w.write_all(&f.to_le_bytes())?;
        }
        start += rows;
    }
    for &y in &ds.y {
        w.write_all(&y.to_le_bytes())?;
    }
    for &f in &ds.difficulty {
        w.write_all(&f.to_le_bytes())?;
    }
    for &b in &ds.is_noisy {
        w.write_all(&[b as u8])?;
    }
    for &c in &ds.cluster {
        w.write_all(&c.to_le_bytes())?;
    }
    w.flush()?;
    artifact_io::sync_file(w.get_ref())?;
    Ok(())
}

/// Read a dataset written by [`save`] — the `anyhow` wrapper over
/// [`load_typed`] that examples and benches call.
pub fn load(path: &Path) -> Result<Dataset> {
    load_typed(path).map_err(|e| anyhow!("loading {path:?}: {e}"))
}

/// Read a dataset written by [`save`], with the typed failure taxonomy.
///
/// Every malformed-content condition — zero-length or short file, bad
/// magic, implausible or over-cap dims, a payload that disagrees with
/// the header — classifies as [`ArtifactError::Corrupt`], never a
/// panic; I/O failures keep their transient/fatal distinction from the
/// facade. The header dims are validated against the file's actual size
/// before the payload is decoded, so truncated or padded files fail
/// with one clear error.
pub fn load_typed(path: &Path) -> Result<Dataset, ArtifactError> {
    let bytes = artifact_io::read_with(Site::CacheLoad, path, READ_STRICT)?;
    const HEADER: usize = 8 + 24;
    if bytes.len() < HEADER {
        return Err(ArtifactError::corrupt(format!(
            "{path:?}: {} bytes on disk is shorter than the {HEADER}-byte header",
            bytes.len()
        )));
    }
    if &bytes[..8] != MAGIC {
        return Err(ArtifactError::corrupt(format!(
            "{path:?}: bad magic (not a CREST dataset file)"
        )));
    }
    let u64_at = |i: usize| u64::from_le_bytes(bytes[i..i + 8].try_into().unwrap());
    let (n64, d64, classes) = (u64_at(8), u64_at(16), u64_at(24) as usize);
    let Some(elems) = n64.checked_mul(d64) else {
        return Err(ArtifactError::corrupt(format!("{path:?}: implausible dims n={n64} d={d64}")));
    };
    if elems > MAX_RESIDENT_ELEMS {
        return Err(ArtifactError::corrupt(format!(
            "{path:?}: n*d = {elems} exceeds the monolithic cache cap ({MAX_RESIDENT_ELEMS}); \
             pack corpora this large into the sharded format (`crest pack`) instead"
        )));
    }
    match expected_len(n64, d64) {
        Some(want) if want == bytes.len() as u64 => {}
        Some(want) => {
            return Err(ArtifactError::corrupt(format!(
                "{path:?}: {} bytes on disk, expected {want} for n={n64} d={d64} \
                 (truncated or corrupt cache)",
                bytes.len()
            )))
        }
        None => {
            return Err(ArtifactError::corrupt(format!(
                "{path:?}: implausible dims n={n64} d={d64}"
            )))
        }
    }
    let (n, d) = (n64 as usize, d64 as usize);
    let (x_at, y_at) = (HEADER, HEADER + n * d * 4);
    let (diff_at, noisy_at, cluster_at) = (y_at + n * 4, y_at + n * 8, y_at + n * 9);

    let x: Vec<f32> = bytes[x_at..y_at]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    let y: Vec<i32> = bytes[y_at..diff_at]
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    let difficulty: Vec<f32> = bytes[diff_at..noisy_at]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    let is_noisy: Vec<bool> = bytes[noisy_at..cluster_at].iter().map(|&b| b != 0).collect();
    let cluster: Vec<u32> = bytes[cluster_at..cluster_at + n * 4]
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect();

    let mat = MatF32::from_vec(n, d, x)
        .map_err(|e| ArtifactError::corrupt(format!("{path:?}: {e}")))?;
    Ok(Dataset::from_mat(mat, y, classes, difficulty, is_noisy, cluster))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("crest_cache_test_{}_{name}", std::process::id()));
        p
    }

    fn small(seed: u64) -> SynthSpec {
        SynthSpec {
            name: "t",
            n_train: 64,
            n_val: 8,
            n_test: 8,
            d: 6,
            classes: 3,
            clusters_per_class: 2,
            redundancy: 0.5,
            label_noise: 0.1,
            margin: 2.0,
            easy_sigma: 0.3,
            hard_sigma: 1.0,
            seed,
        }
    }

    #[test]
    fn roundtrip() {
        let ds = generate(&small(3)).train;
        let path = tmpfile("roundtrip.bin");
        save(&ds, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.to_mat().data, ds.to_mat().data);
        assert_eq!(back.y, ds.y);
        assert_eq!(back.difficulty, ds.difficulty);
        assert_eq!(back.is_noisy, ds.is_noisy);
        assert_eq!(back.cluster, ds.cluster);
        assert_eq!(back.classes, ds.classes);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmpfile("badmagic.bin");
        std::fs::write(&path, b"NOTADATASET_____").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_truncated_with_expected_size() {
        let ds = generate(&small(4)).train;
        let path = tmpfile("trunc.bin");
        save(&ds, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        let err = load(&path).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("expected"), "unhelpful error: {msg}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_trailing_bytes_up_front() {
        let ds = generate(&small(5)).train;
        let path = tmpfile("trailing.bin");
        save(&ds, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(&[0u8; 7]);
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("expected"));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_corrupt_header_dims() {
        let ds = generate(&small(6)).train;
        let path = tmpfile("dims.bin");
        save(&ds, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // overwrite n with an absurd value; size check must catch it
        bytes[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn edge_cases_classify_as_corrupt_not_panic() {
        // zero-length file: shorter than the header
        let path = tmpfile("zerolen.bin");
        std::fs::write(&path, b"").unwrap();
        let err = load_typed(&path).unwrap_err();
        assert!(matches!(err, ArtifactError::Corrupt(_)), "{err}");
        assert!(err.to_string().contains("header"), "{err}");
        std::fs::remove_file(&path).ok();

        // truncated payload: header parses, size check catches it
        let ds = generate(&small(7)).train;
        let path = tmpfile("typed_trunc.bin");
        save(&ds, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 5]).unwrap();
        let err = load_typed(&path).unwrap_err();
        assert!(matches!(err, ArtifactError::Corrupt(_)), "{err}");
        assert!(err.to_string().contains("expected"), "{err}");
        std::fs::remove_file(&path).ok();

        // oversized header dims vs the n*d cap
        let path = tmpfile("typed_huge.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&(1u64 << 33).to_le_bytes()); // n
        bytes.extend_from_slice(&8u64.to_le_bytes()); // d
        bytes.extend_from_slice(&2u64.to_le_bytes()); // classes
        std::fs::write(&path, &bytes).unwrap();
        let err = load_typed(&path).unwrap_err();
        assert!(matches!(err, ArtifactError::Corrupt(_)), "{err}");
        assert!(err.to_string().contains("cap"), "{err}");
        std::fs::remove_file(&path).ok();

        // a missing file is NOT corruption — it keeps the I/O taxonomy
        let err = load_typed(&tmpfile("never_written.bin")).unwrap_err();
        assert!(err.is_not_found(), "{err}");
    }

    #[test]
    fn oversized_dims_point_at_sharded_format() {
        let path = tmpfile("huge.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&(1u64 << 32).to_le_bytes()); // n
        bytes.extend_from_slice(&16u64.to_le_bytes()); // d
        bytes.extend_from_slice(&4u64.to_le_bytes()); // classes
        std::fs::write(&path, &bytes).unwrap();
        let msg = format!("{:#}", load(&path).unwrap_err());
        assert!(msg.contains("crest pack"), "cap error should redirect: {msg}");
        std::fs::remove_file(path).ok();
    }
}
