//! Binary on-disk dataset format (write once, memory-load fast).
//!
//! Examples and benches cache generated corpora so repeated runs skip
//! synthesis. Format (little-endian):
//!
//! ```text
//! magic   8 bytes  "CRSTDS1\0"
//! n       u64      examples
//! d       u64      feature dim
//! classes u64
//! x       n*d f32
//! y       n   i32
//! difficulty n f32
//! is_noisy   n u8
//! cluster    n u32
//! ```

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::data::dataset::Dataset;
use crate::tensor::MatF32;

const MAGIC: &[u8; 8] = b"CRSTDS1\0";

/// Write a dataset to the binary cache format at `path`.
pub fn save(ds: &Dataset, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path).with_context(|| format!("create {path:?}"))?);
    w.write_all(MAGIC)?;
    for v in [ds.n() as u64, ds.d() as u64, ds.classes as u64] {
        w.write_all(&v.to_le_bytes())?;
    }
    for &f in &ds.x.data {
        w.write_all(&f.to_le_bytes())?;
    }
    for &y in &ds.y {
        w.write_all(&y.to_le_bytes())?;
    }
    for &f in &ds.difficulty {
        w.write_all(&f.to_le_bytes())?;
    }
    for &b in &ds.is_noisy {
        w.write_all(&[b as u8])?;
    }
    for &c in &ds.cluster {
        w.write_all(&c.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Read a dataset written by [`save`].
pub fn load(path: &Path) -> Result<Dataset> {
    let mut r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: bad magic (not a CREST dataset file)");
    }
    let mut u64buf = [0u8; 8];
    let mut read_u64 = |r: &mut BufReader<File>| -> Result<u64> {
        r.read_exact(&mut u64buf)?;
        Ok(u64::from_le_bytes(u64buf))
    };
    let n = read_u64(&mut r)? as usize;
    let d = read_u64(&mut r)? as usize;
    let classes = read_u64(&mut r)? as usize;
    if n.checked_mul(d).is_none() || n * d > (1 << 31) {
        bail!("{path:?}: implausible dims n={n} d={d}");
    }

    let mut xbuf = vec![0u8; n * d * 4];
    r.read_exact(&mut xbuf)?;
    let x: Vec<f32> = xbuf.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect();

    let mut ybuf = vec![0u8; n * 4];
    r.read_exact(&mut ybuf)?;
    let y: Vec<i32> = ybuf.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect();

    let mut dbuf = vec![0u8; n * 4];
    r.read_exact(&mut dbuf)?;
    let difficulty: Vec<f32> =
        dbuf.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect();

    let mut nbuf = vec![0u8; n];
    r.read_exact(&mut nbuf)?;
    let is_noisy: Vec<bool> = nbuf.iter().map(|&b| b != 0).collect();

    let mut cbuf = vec![0u8; n * 4];
    r.read_exact(&mut cbuf)?;
    let cluster: Vec<u32> =
        cbuf.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect();

    // trailing garbage check
    let mut extra = [0u8; 1];
    if r.read(&mut extra)? != 0 {
        bail!("{path:?}: trailing bytes after dataset payload");
    }

    Ok(Dataset {
        x: MatF32::from_vec(n, d, x)?,
        y,
        classes,
        difficulty,
        is_noisy,
        cluster,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("crest_cache_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip() {
        let spec = SynthSpec {
            name: "t",
            n_train: 64,
            n_val: 8,
            n_test: 8,
            d: 6,
            classes: 3,
            clusters_per_class: 2,
            redundancy: 0.5,
            label_noise: 0.1,
            margin: 2.0,
            easy_sigma: 0.3,
            hard_sigma: 1.0,
            seed: 3,
        };
        let ds = generate(&spec).train;
        let path = tmpfile("roundtrip.bin");
        save(&ds, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.x.data, ds.x.data);
        assert_eq!(back.y, ds.y);
        assert_eq!(back.difficulty, ds.difficulty);
        assert_eq!(back.is_noisy, ds.is_noisy);
        assert_eq!(back.cluster, ds.cluster);
        assert_eq!(back.classes, ds.classes);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmpfile("badmagic.bin");
        std::fs::write(&path, b"NOTADATASET_____").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_truncated() {
        let spec = SynthSpec {
            name: "t",
            n_train: 16,
            n_val: 4,
            n_test: 4,
            d: 4,
            classes: 2,
            clusters_per_class: 1,
            redundancy: 0.5,
            label_noise: 0.0,
            margin: 2.0,
            easy_sigma: 0.3,
            hard_sigma: 1.0,
            seed: 4,
        };
        let ds = generate(&spec).train;
        let path = tmpfile("trunc.bin");
        save(&ds, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
