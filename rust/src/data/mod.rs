//! Data substrate: datasets, synthetic corpus generation, on-disk cache,
//! prefetching loader.

pub mod cache;
pub mod dataset;
pub mod loader;
pub mod synth;

pub use dataset::{Dataset, Splits};
pub use synth::{generate, SynthSpec};
