//! Data substrate: pluggable feature stores, sharded on-disk packs,
//! synthetic corpus generation, on-disk cache, prefetching loader.
//!
//! The split preparation entry points ([`prepare_splits`] /
//! [`prepare_spec_splits`]) honor the session-wide store selection
//! (`--data-store` / `CREST_DATA_STORE`): under [`StoreKind::Mem`] they
//! generate resident splits; under [`StoreKind::Mmap`] they lazily pack
//! the corpus into the sharded format (under `CREST_PACK_DIR`, or the
//! system temp dir) and hand back mmap-backed handles. Both paths yield
//! bitwise-identical features, so every report downstream is identical
//! regardless of store.

pub mod cache;
pub mod dataset;
pub mod loader;
pub mod shard;
pub mod store;
pub mod synth;

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

pub use dataset::{Dataset, Splits};
pub use store::{
    default_store, set_default_store, DataStore, MemStore, MmapStore, StoreFallback, StoreKind,
};
pub use synth::{generate, generate_packed, SynthSpec};

/// Root directory for lazily packed corpora: `CREST_PACK_DIR` (or a
/// session [`RuntimeConfig`](crate::runtime_config::RuntimeConfig)
/// override) if set, else `<tmp>/crest-pack`.
pub fn pack_root() -> PathBuf {
    crate::runtime_config::RuntimeConfig::current().resolved_pack_root()
}

/// Materialize the splits for `spec` through the session's default store.
pub fn prepare_spec_splits(spec: &SynthSpec) -> Result<Arc<Splits>> {
    match default_store() {
        StoreKind::Mem => Ok(Arc::new(generate(spec))),
        StoreKind::Mmap => {
            let root = pack_root().join(format!("{}-s{}", spec.name, spec.seed));
            generate_packed(spec, &root, shard::DEFAULT_SHARD_ROWS)
                .with_context(|| format!("packing corpus at {root:?}"))?;
            let splits = shard::load_packed_splits(&root)
                .with_context(|| format!("loading packed corpus at {root:?}"))?;
            Ok(Arc::new(splits))
        }
    }
}

/// Materialize the splits for a named variant + seed through the
/// session's default store.
pub fn prepare_splits(variant: &str, seed: u64) -> Result<Arc<Splits>> {
    let Some(spec) = SynthSpec::preset(variant, seed) else {
        bail!("unknown data variant '{variant}'");
    };
    prepare_spec_splits(&spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prepare_splits_rejects_unknown_variant() {
        assert!(prepare_splits("bogus", 0).is_err());
    }

    #[test]
    fn prepare_splits_honors_store_kinds() {
        let base = SynthSpec::preset("smoke", 77).unwrap();
        let spec = SynthSpec { n_train: 64, n_val: 16, n_test: 16, ..base };
        let prev = default_store();
        set_default_store(StoreKind::Mem);
        let mem = prepare_spec_splits(&spec).unwrap();
        assert_eq!(mem.train.store_kind(), "mem");
        // route the lazy pack to a private dir so parallel tests can't collide
        let dir = std::env::temp_dir().join(format!("crest_prepare_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::env::set_var("CREST_PACK_DIR", &dir);
        set_default_store(StoreKind::Mmap);
        let mm = prepare_spec_splits(&spec).unwrap();
        std::env::remove_var("CREST_PACK_DIR");
        set_default_store(prev);
        assert_eq!(mm.train.store_kind(), "mmap");
        assert_eq!(mem.train.to_mat().data, mm.train.to_mat().data);
        assert_eq!(mem.val.y, mm.val.y);
        std::fs::remove_dir_all(&dir).ok();
    }
}
