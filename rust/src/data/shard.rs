//! The sharded on-disk dataset format behind [`super::store::MmapStore`].
//!
//! A packed split is a directory:
//!
//! ```text
//! meta.json        {"version":1,"n":…,"d":…,"classes":…,"shard_rows":…,"n_shards":…}
//! labels.bin       magic "CRSTSH1\0", n u64, then y (n i32le),
//!                  difficulty (n f32le), is_noisy (n u8), cluster (n u32le)
//! shard_00000.bin  raw f32le feature rows (shard_rows rows; last may be short)
//! …
//! ```
//!
//! Feature shards carry no header so every row offset is a multiple of 4
//! and a mapping can be indexed directly; all bookkeeping lives in
//! `meta.json`. Labels and provenance stay RAM-resident (13 bytes/example
//! — ~13 MB at 10^6 examples) while features, the dominant `n*d` payload,
//! go through the store. Unlike the monolithic [`super::cache`] format
//! there is no element-count cap: shards are what `crest pack` and the
//! ≥10^6-example scaling scenario write.
//!
//! All sizes are validated against file metadata up front, and packs
//! written by this version carry a per-file CRC-32 table in `meta.json`
//! that is verified on every load — so a truncated, torn, or bit-flipped
//! pack fails loudly at load (naming the file at fault) instead of
//! handing garbage floats to training. Packs from older versions carry
//! no `crc` key and load without content verification. All filesystem
//! touches go through [`crate::util::artifact_io`] (the `IO-FACADE`
//! contract), so fault injection and bounded transient retry cover the
//! whole surface.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::data::dataset::{Dataset, Splits};
use crate::data::store::MmapStore;
use crate::util::artifact_io::{self, Crc32, READ_STRICT, WRITE_STRICT};
use crate::util::faults::Site;
use crate::util::json::Json;

/// Default rows per shard file (`8192 * d * 4` bytes per shard).
pub const DEFAULT_SHARD_ROWS: usize = 8192;

const LABELS_MAGIC: &[u8; 8] = b"CRSTSH1\0";

/// Shard-file name of shard `s`.
pub fn shard_file(s: usize) -> String {
    format!("shard_{s:05}.bin")
}

/// The parsed `meta.json` of one packed split.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackMeta {
    /// Examples in the split.
    pub n: usize,
    /// Feature dimensionality.
    pub d: usize,
    /// Number of classes.
    pub classes: usize,
    /// Rows per shard (last shard may be short).
    pub shard_rows: usize,
    /// Number of shard files.
    pub n_shards: usize,
    /// Per-file CRC-32 table (`labels.bin` + each shard), in file-name
    /// order. Empty for packs written before integrity landed — those
    /// load without content verification.
    pub crc: Vec<(String, u32)>,
}

impl PackMeta {
    fn new(n: usize, d: usize, classes: usize, shard_rows: usize) -> PackMeta {
        let n_shards = if n == 0 { 0 } else { (n + shard_rows - 1) / shard_rows };
        PackMeta { n, d, classes, shard_rows, n_shards, crc: Vec::new() }
    }

    /// The recorded CRC-32 for `file`, if the pack carries one.
    pub fn crc_of(&self, file: &str) -> Option<u32> {
        self.crc.iter().find(|(name, _)| name == file).map(|&(_, c)| c)
    }

    fn save(&self, dir: &Path) -> Result<()> {
        let mut crc = Json::obj();
        for (name, c) in &self.crc {
            crc = crc.set(name, *c as usize);
        }
        let j = Json::obj()
            .set("version", 1usize)
            .set("n", self.n)
            .set("d", self.d)
            .set("classes", self.classes)
            .set("shard_rows", self.shard_rows)
            .set("n_shards", self.n_shards)
            .set("crc", crc);
        // meta.json is the pack's commit record (`is_packed` keys off its
        // existence), so it publishes atomically with full fsync ordering
        let path = dir.join("meta.json");
        artifact_io::publish_with(Site::PackWrite, &path, j.to_string_pretty().as_bytes(), WRITE_STRICT)
            .with_context(|| format!("publishing {path:?}"))?;
        Ok(())
    }

    /// Read and validate a packed split's `meta.json`.
    pub fn load(dir: &Path) -> Result<PackMeta> {
        let path = dir.join("meta.json");
        let text = artifact_io::read_to_string_with(Site::PackRead, &path, READ_STRICT)
            .with_context(|| format!("read {path:?}"))?;
        let j = Json::parse(&text).with_context(|| format!("parse {path:?}"))?;
        let version = j.req("version")?.as_usize()?;
        if version != 1 {
            bail!("{path:?}: unsupported pack version {version}");
        }
        let mut meta = PackMeta::new(
            j.req("n")?.as_usize()?,
            j.req("d")?.as_usize()?,
            j.req("classes")?.as_usize()?,
            j.req("shard_rows")?.as_usize()?,
        );
        if meta.n_shards != j.req("n_shards")?.as_usize()? {
            bail!("{path:?}: n_shards inconsistent with n and shard_rows");
        }
        if meta.shard_rows == 0 && meta.n > 0 {
            bail!("{path:?}: shard_rows must be positive");
        }
        if let Some(crc) = j.get("crc") {
            for (name, val) in crc.as_obj()? {
                let c = val.as_usize()?;
                if c > u32::MAX as usize {
                    bail!("{path:?}: crc entry {name} out of range");
                }
                meta.crc.push((name.clone(), c as u32));
            }
        }
        Ok(meta)
    }
}

// ------------------------------------------------------------------ write

/// Incremental writer for one packed split: rows stream in block by
/// block, labels/provenance accumulate in RAM, and [`SplitWriter::finish`]
/// seals the directory. Used by [`pack_dataset`] and by the streaming
/// synthesis path ([`crate::data::synth::generate_packed`]), so a corpus
/// never has to be resident to be packed.
pub struct SplitWriter {
    dir: PathBuf,
    meta: PackMeta,
    rows_written: usize,
    shard: Option<(BufWriter<File>, Crc32)>,
    shard_idx: usize,
    rows_in_shard: usize,
    y: Vec<i32>,
    difficulty: Vec<f32>,
    is_noisy: Vec<bool>,
    cluster: Vec<u32>,
}

impl SplitWriter {
    /// Start a packed split of `n` rows at `dir` (created if missing).
    pub fn create(
        dir: &Path,
        n: usize,
        d: usize,
        classes: usize,
        shard_rows: usize,
    ) -> Result<Self> {
        if shard_rows == 0 {
            bail!("shard_rows must be positive");
        }
        artifact_io::create_dir_all(dir).with_context(|| format!("create {dir:?}"))?;
        Ok(SplitWriter {
            dir: dir.to_path_buf(),
            meta: PackMeta::new(n, d, classes, shard_rows),
            rows_written: 0,
            shard: None,
            shard_idx: 0,
            rows_in_shard: 0,
            y: Vec::with_capacity(n),
            difficulty: Vec::with_capacity(n),
            is_noisy: Vec::with_capacity(n),
            cluster: Vec::with_capacity(n),
        })
    }

    /// Append one example (feature row + labels/provenance).
    pub fn push_row(
        &mut self,
        row: &[f32],
        y: i32,
        difficulty: f32,
        noisy: bool,
        cluster: u32,
    ) -> Result<()> {
        if row.len() != self.meta.d {
            bail!("row has {} features, pack wants {}", row.len(), self.meta.d);
        }
        if self.rows_written >= self.meta.n {
            bail!("pack already holds the declared {} rows", self.meta.n);
        }
        if self.shard.is_none() {
            let path = self.dir.join(shard_file(self.shard_idx));
            let f = artifact_io::create(Site::PackWrite, &path)
                .with_context(|| format!("create {path:?}"))?;
            self.shard = Some((BufWriter::new(f), Crc32::new()));
            self.rows_in_shard = 0;
        }
        let (w, crc) = self.shard.as_mut().expect("shard writer opened above");
        for v in row {
            let bytes = v.to_le_bytes();
            w.write_all(&bytes)?;
            crc.update(&bytes);
        }
        self.rows_in_shard += 1;
        self.rows_written += 1;
        if self.rows_in_shard == self.meta.shard_rows {
            self.close_shard()?;
        }
        self.y.push(y);
        self.difficulty.push(difficulty);
        self.is_noisy.push(noisy);
        self.cluster.push(cluster);
        Ok(())
    }

    /// Flush + fsync the open shard and record its CRC in the meta table.
    fn close_shard(&mut self) -> Result<()> {
        if let Some((mut w, crc)) = self.shard.take() {
            w.flush()?;
            artifact_io::sync_file(w.get_ref())?;
            self.meta.crc.push((shard_file(self.shard_idx), crc.finish()));
            self.shard_idx += 1;
        }
        Ok(())
    }

    /// Seal the pack: flush + fsync the tail shard and `labels.bin`,
    /// then atomically publish `meta.json` — the commit record carrying
    /// every file's CRC-32.
    pub fn finish(mut self) -> Result<PackMeta> {
        if self.rows_written != self.meta.n {
            bail!("pack got {} of the declared {} rows", self.rows_written, self.meta.n);
        }
        self.close_shard()?;
        let path = self.dir.join("labels.bin");
        let f = artifact_io::create(Site::PackWrite, &path)
            .with_context(|| format!("create {path:?}"))?;
        let mut w = BufWriter::new(f);
        let mut crc = Crc32::new();
        let mut put = |w: &mut BufWriter<File>, crc: &mut Crc32, bytes: &[u8]| -> Result<()> {
            w.write_all(bytes)?;
            crc.update(bytes);
            Ok(())
        };
        put(&mut w, &mut crc, LABELS_MAGIC)?;
        put(&mut w, &mut crc, &(self.meta.n as u64).to_le_bytes())?;
        for v in &self.y {
            put(&mut w, &mut crc, &v.to_le_bytes())?;
        }
        for v in &self.difficulty {
            put(&mut w, &mut crc, &v.to_le_bytes())?;
        }
        for &b in &self.is_noisy {
            put(&mut w, &mut crc, &[b as u8])?;
        }
        for v in &self.cluster {
            put(&mut w, &mut crc, &v.to_le_bytes())?;
        }
        w.flush()?;
        artifact_io::sync_file(w.get_ref())?;
        self.meta.crc.push(("labels.bin".to_string(), crc.finish()));
        self.meta.crc.sort();
        self.meta.save(&self.dir)?;
        Ok(self.meta)
    }
}

/// Pack an in-memory dataset into the sharded format at `dir`. Features
/// stream through a block buffer, so this also works for re-packing an
/// already disk-backed dataset without materializing it.
pub fn pack_dataset(ds: &Dataset, dir: &Path, shard_rows: usize) -> Result<PackMeta> {
    let (n, d) = (ds.n(), ds.d());
    let mut w = SplitWriter::create(dir, n, d, ds.classes, shard_rows)?;
    let block = shard_rows.min(n.max(1));
    let mut buf = vec![0.0f32; block * d];
    let mut start = 0;
    while start < n {
        let rows = block.min(n - start);
        ds.read_block(start, rows, &mut buf[..rows * d]);
        for k in 0..rows {
            let i = start + k;
            let row = &buf[k * d..(k + 1) * d];
            w.push_row(row, ds.y[i], ds.difficulty[i], ds.is_noisy[i], ds.cluster[i])?;
        }
        start += rows;
    }
    w.finish()
}

/// Pack all three splits under `root` (`root/train`, `root/val`,
/// `root/test`).
pub fn pack_splits(splits: &Splits, root: &Path, shard_rows: usize) -> Result<()> {
    for (name, ds) in [("train", &splits.train), ("val", &splits.val), ("test", &splits.test)] {
        pack_dataset(ds, &root.join(name), shard_rows)?;
    }
    Ok(())
}

// ------------------------------------------------------------------- read

fn load_labels(
    dir: &Path,
    n: usize,
    want_crc: Option<u32>,
) -> Result<(Vec<i32>, Vec<f32>, Vec<bool>, Vec<u32>)> {
    let path = dir.join("labels.bin");
    let bytes = artifact_io::read_with(Site::PackRead, &path, READ_STRICT)
        .with_context(|| format!("read {path:?}"))?;
    let want = 16 + n * 13;
    if bytes.len() != want {
        bail!("{path:?}: {} bytes on disk, expected {want} for n={n}", bytes.len());
    }
    if let Some(c) = want_crc {
        let got = artifact_io::crc32(&bytes);
        if got != c {
            bail!("{path:?}: CRC-32 mismatch ({got:08x} on disk, meta says {c:08x})");
        }
    }
    if &bytes[..8] != LABELS_MAGIC {
        bail!("{path:?}: bad magic (not a CREST shard-labels file)");
    }
    if u64::from_le_bytes(bytes[8..16].try_into().unwrap()) != n as u64 {
        bail!("{path:?}: row count disagrees with meta.json");
    }
    let (y_at, diff_at, noisy_at, cluster_at) = (16, 16 + n * 4, 16 + n * 8, 16 + n * 9);
    let y = bytes[y_at..y_at + n * 4]
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    let difficulty = bytes[diff_at..diff_at + n * 4]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    let is_noisy = bytes[noisy_at..noisy_at + n].iter().map(|&b| b != 0).collect();
    let cluster = bytes[cluster_at..cluster_at + n * 4]
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok((y, difficulty, is_noisy, cluster))
}

/// Load one packed split as an mmap-backed [`Dataset`]. Features stay on
/// disk behind [`MmapStore`]; labels and provenance load into RAM. When
/// `meta.json` carries a CRC table, every file's content is verified
/// here — a flipped byte anywhere in the pack fails the load naming the
/// file, it never reaches training as garbage floats.
pub fn load_packed(dir: &Path) -> Result<Dataset> {
    let meta = PackMeta::load(dir)?;
    let (y, difficulty, is_noisy, cluster) = load_labels(dir, meta.n, meta.crc_of("labels.bin"))?;
    let paths: Vec<PathBuf> = (0..meta.n_shards).map(|s| dir.join(shard_file(s))).collect();
    for (s, path) in paths.iter().enumerate() {
        let Some(want) = meta.crc_of(&shard_file(s)) else { continue };
        let bytes = artifact_io::read_with(Site::PackRead, path, READ_STRICT)
            .with_context(|| format!("read {path:?}"))?;
        let got = artifact_io::crc32(&bytes);
        if got != want {
            bail!("shard {path:?}: CRC-32 mismatch ({got:08x} on disk, meta says {want:08x})");
        }
    }
    let store = MmapStore::open(&paths, meta.n, meta.d, meta.shard_rows.max(1))
        .with_context(|| format!("opening shards under {dir:?}"))?;
    Ok(Dataset::with_store(Arc::new(store), y, meta.classes, difficulty, is_noisy, cluster))
}

/// Load all three packed splits under `root`.
pub fn load_packed_splits(root: &Path) -> Result<Splits> {
    Ok(Splits {
        train: load_packed(&root.join("train"))?,
        val: load_packed(&root.join("val"))?,
        test: load_packed(&root.join("test"))?,
    })
}

/// True when `root` holds all three packed splits.
pub fn is_packed(root: &Path) -> bool {
    ["train", "val", "test"].iter().all(|s| root.join(s).join("meta.json").exists())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};

    fn tdir(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("crest_shard_test_{}_{name}", std::process::id()))
    }

    fn small() -> SynthSpec {
        SynthSpec {
            name: "t",
            n_train: 130,
            n_val: 17,
            n_test: 9,
            d: 6,
            classes: 3,
            clusters_per_class: 2,
            redundancy: 0.5,
            label_noise: 0.1,
            margin: 2.0,
            easy_sigma: 0.3,
            hard_sigma: 1.0,
            seed: 11,
        }
    }

    #[test]
    fn pack_load_roundtrip_bitwise() {
        let splits = generate(&small());
        let root = tdir("roundtrip");
        let _ = std::fs::remove_dir_all(&root);
        // shard_rows=32 gives a short tail shard on every split
        pack_splits(&splits, &root, 32).unwrap();
        let back = load_packed_splits(&root).unwrap();
        for (a, b) in [
            (&splits.train, &back.train),
            (&splits.val, &back.val),
            (&splits.test, &back.test),
        ] {
            assert_eq!(b.store_kind(), "mmap");
            assert_eq!(a.to_mat().data, b.to_mat().data);
            assert_eq!(a.y, b.y);
            assert_eq!(a.difficulty, b.difficulty);
            assert_eq!(a.is_noisy, b.is_noisy);
            assert_eq!(a.cluster, b.cluster);
            assert_eq!(a.classes, b.classes);
        }
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn load_rejects_truncated_labels_and_shards() {
        let splits = generate(&small());
        let root = tdir("trunc");
        let _ = std::fs::remove_dir_all(&root);
        pack_splits(&splits, &root, 64).unwrap();
        // truncated labels sidecar: caught by the up-front size check
        let labels = root.join("val").join("labels.bin");
        let bytes = std::fs::read(&labels).unwrap();
        std::fs::write(&labels, &bytes[..bytes.len() - 3]).unwrap();
        let err = load_packed(&root.join("val")).unwrap_err();
        assert!(format!("{err:#}").contains("expected"), "{err:#}");
        // truncated feature shard: caught when the store opens
        let shard = root.join("train").join(shard_file(0));
        let bytes = std::fs::read(&shard).unwrap();
        std::fs::write(&shard, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load_packed(&root.join("train")).is_err());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn writer_enforces_declared_row_count() {
        let root = tdir("count");
        let _ = std::fs::remove_dir_all(&root);
        let mut w = SplitWriter::create(&root, 2, 3, 2, 8).unwrap();
        w.push_row(&[0.0, 1.0, 2.0], 0, 0.0, false, 0).unwrap();
        // short: finish must refuse
        let err = SplitWriter::create(&tdir("count2"), 2, 3, 2, 8).unwrap().finish().unwrap_err();
        assert!(format!("{err:#}").contains("declared"));
        // wrong width
        assert!(w.push_row(&[0.0], 1, 0.0, false, 0).is_err());
        w.push_row(&[3.0, 4.0, 5.0], 1, 0.5, true, 1).unwrap();
        // overflow
        assert!(w.push_row(&[6.0, 7.0, 8.0], 0, 0.0, false, 0).is_err());
        let meta = w.finish().unwrap();
        assert_eq!((meta.n, meta.n_shards), (2, 1));
        std::fs::remove_dir_all(&root).ok();
        std::fs::remove_dir_all(tdir("count2")).ok();
    }
}
