//! Pluggable feature storage — the out-of-core substrate under `Dataset`.
//!
//! A [`DataStore`] owns the feature matrix of one split and serves it
//! through chunked block reads, so everything above (batch gathers,
//! subsetting, the prefetching loader, selection embeddings) is agnostic
//! to whether rows live in RAM or in sharded files on disk:
//!
//! * [`MemStore`] — the historical in-RAM `MatF32` (zero-cost reads);
//! * [`MmapStore`] — fixed-size row shards written by `crest pack` (see
//!   [`super::shard`]), memory-mapped read-only via a raw `mmap(2)` FFI
//!   call, degrading per shard to `pread(2)` when mapping fails and to a
//!   resident buffer on non-unix hosts.
//!
//! Shard payloads are raw little-endian f32 rows, so a read decodes to
//! exactly the bytes synthesis produced — mem- and mmap-backed runs are
//! bitwise-identical by construction (asserted by the `data_store`
//! integration tests).
//!
//! The process-wide default backend is selected with
//! [`set_default_store`] (`--data-store` / `CREST_DATA_STORE`); consumers
//! go through [`crate::data::prepare_splits`].

use std::fmt;
use std::fs::File;
use std::sync::{OnceLock, RwLock};

use anyhow::{bail, Context, Result};

use crate::tensor::MatF32;
use crate::util::artifact_io;
use crate::util::faults::Site;

/// Feature storage of one split: `n` rows of `d` f32 features, served
/// through block reads.
pub trait DataStore: Send + Sync + fmt::Debug {
    /// Number of rows.
    fn n(&self) -> usize;

    /// Feature dimensionality (row width).
    fn d(&self) -> usize;

    /// Backend name for reports and tests (`"mem"` / `"mmap"`).
    fn kind(&self) -> &'static str;

    /// Copy the contiguous block of `rows` rows starting at `start` into
    /// `out` (`rows * d` elements).
    fn read_rows(&self, start: usize, rows: usize, out: &mut [f32]);

    /// Gather arbitrary rows into `out` (`idx.len() * d` elements) — the
    /// batch-assembly primitive. The default goes row by row through
    /// [`DataStore::read_rows`]; backends override with cheaper paths.
    fn gather_into(&self, idx: &[usize], out: &mut [f32]) {
        let d = self.d();
        debug_assert_eq!(out.len(), idx.len() * d);
        for (k, &i) in idx.iter().enumerate() {
            self.read_rows(i, 1, &mut out[k * d..(k + 1) * d]);
        }
    }
}

// ------------------------------------------------------------------- mem

/// The in-RAM store: a plain row-major `MatF32` (the pre-refactor
/// representation, now behind the trait).
#[derive(Debug)]
pub struct MemStore {
    x: MatF32,
}

impl MemStore {
    /// Wrap an in-memory feature matrix.
    pub fn new(x: MatF32) -> MemStore {
        MemStore { x }
    }
}

impl DataStore for MemStore {
    fn n(&self) -> usize {
        self.x.rows
    }

    fn d(&self) -> usize {
        self.x.cols
    }

    fn kind(&self) -> &'static str {
        "mem"
    }

    fn read_rows(&self, start: usize, rows: usize, out: &mut [f32]) {
        let d = self.x.cols;
        out[..rows * d].copy_from_slice(&self.x.data[start * d..(start + rows) * d]);
    }

    fn gather_into(&self, idx: &[usize], out: &mut [f32]) {
        let d = self.x.cols;
        debug_assert_eq!(out.len(), idx.len() * d);
        for (o, &i) in out.chunks_exact_mut(d).zip(idx) {
            o.copy_from_slice(self.x.row(i));
        }
    }
}

// ------------------------------------------------------------------ mmap

/// Decode packed little-endian f32 bytes into `dst`.
pub(crate) fn decode_f32le(src: &[u8], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len() * 4);
    for (o, c) in dst.iter_mut().zip(src.chunks_exact(4)) {
        *o = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
    }
}

#[cfg(unix)]
#[allow(unsafe_code)] // the crate-wide deny's one exception: raw mmap(2)
mod mm {
    //! Minimal read-only `mmap(2)` binding. The offline crate registry has
    //! no `libc`/`memmap2`, so the two syscalls are declared directly;
    //! constants are the Linux/BSD values for a read-only private mapping.
    use std::ffi::c_void;
    use std::fs::File;
    use std::os::unix::io::AsRawFd;

    extern "C" {
        fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        fn munmap(addr: *mut c_void, len: usize) -> i32;
    }

    const PROT_READ: i32 = 1;
    const MAP_PRIVATE: i32 = 2;

    /// One read-only private mapping of a whole shard file.
    pub struct Mapping {
        ptr: *const u8,
        len: usize,
    }

    // SAFETY: the mapping is immutable (PROT_READ, MAP_PRIVATE) for its
    // whole lifetime, so sharing the pointer across threads is sound.
    unsafe impl Send for Mapping {}
    // SAFETY: same immutability argument as Send — readers never observe
    // a write because none exist.
    unsafe impl Sync for Mapping {}

    impl Mapping {
        /// Map `len` bytes of `file` read-only; `None` when the kernel
        /// refuses (callers fall back to pread).
        pub fn map(file: &File, len: usize) -> Option<Mapping> {
            if len == 0 {
                return None;
            }
            // SAFETY: plain syscall with a live fd; the kernel validates
            // len/fd and we check the return value before trusting it
            let p = unsafe {
                mmap(std::ptr::null_mut(), len, PROT_READ, MAP_PRIVATE, file.as_raw_fd(), 0)
            };
            if p.is_null() || p as isize == -1 {
                return None;
            }
            Some(Mapping { ptr: p as *const u8, len })
        }

        /// The mapped bytes.
        pub fn bytes(&self) -> &[u8] {
            // SAFETY: ptr..ptr+len is a live PROT_READ mapping for the
            // whole &self lifetime (unmapped only in Drop)
            unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
        }
    }

    impl Drop for Mapping {
        fn drop(&mut self) {
            // SAFETY: ptr/len came from a successful mmap and are
            // unmapped exactly once, here
            unsafe {
                munmap(self.ptr as *mut c_void, self.len);
            }
        }
    }

    impl std::fmt::Debug for Mapping {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "Mapping({} bytes)", self.len)
        }
    }
}

/// How one shard's bytes are served.
#[derive(Debug)]
enum ShardData {
    /// Memory-mapped read-only (the fast path).
    #[cfg(unix)]
    Mapped(mm::Mapping),
    /// Positional reads (`pread`) when the kernel refuses to map — the
    /// first rung of the degradation ladder.
    #[cfg(unix)]
    Pread(File),
    /// Whole shard resident in RAM — the non-unix default, and the
    /// second degradation rung (`CREST_STORE_FALLBACK=mem`) for hosts
    /// where pread on the held fd is also failing.
    Resident(Vec<f32>),
}

#[derive(Debug)]
struct Shard {
    data: ShardData,
    rows: usize,
    /// Kept so a mid-run read failure can name the artifact at fault.
    #[cfg_attr(not(unix), allow(dead_code))]
    path: std::path::PathBuf,
}

/// Sharded on-disk store: fixed-size row chunks, one raw-f32le file per
/// shard, written by [`super::shard::pack_dataset`].
#[derive(Debug)]
pub struct MmapStore {
    n: usize,
    d: usize,
    shard_rows: usize,
    shards: Vec<Shard>,
}

impl MmapStore {
    /// Open the shard files of one split. `paths` must be in shard order;
    /// shard `s` holds rows `[s*shard_rows, min((s+1)*shard_rows, n))`.
    /// Each file's size is validated against its expected row count up
    /// front, so a truncated shard fails here with a clear error instead
    /// of mid-training.
    pub fn open(
        paths: &[std::path::PathBuf],
        n: usize,
        d: usize,
        shard_rows: usize,
    ) -> Result<Self> {
        if shard_rows == 0 {
            bail!("shard_rows must be positive");
        }
        let want_shards = if n == 0 { 0 } else { (n + shard_rows - 1) / shard_rows };
        if paths.len() != want_shards {
            bail!(
                "expected {want_shards} shard files for n={n} shard_rows={shard_rows}, got {}",
                paths.len()
            );
        }
        let mut shards = Vec::with_capacity(paths.len());
        for (s, path) in paths.iter().enumerate() {
            let rows = shard_rows.min(n - s * shard_rows);
            let want = (rows as u64) * (d as u64) * 4;
            let file = artifact_io::open(Site::PackRead, path)
                .with_context(|| format!("open shard {path:?}"))?;
            let got = file.metadata()?.len();
            if got != want {
                bail!(
                    "shard {path:?}: {got} bytes on disk, expected {want} ({rows} rows x {d} f32)"
                );
            }
            shards.push(Shard {
                data: Self::shard_data(file, want as usize, path)?,
                rows,
                path: path.clone(),
            });
        }
        Ok(MmapStore { n, d, shard_rows, shards })
    }

    /// Serve one shard, walking the degradation ladder: mmap → pread →
    /// (with `CREST_STORE_FALLBACK=mem`) a resident copy. The `mmap-map`
    /// fault site simulates a kernel that refuses the mapping.
    #[cfg(unix)]
    fn shard_data(file: File, len: usize, path: &std::path::Path) -> Result<ShardData> {
        let refused = crate::util::faults::draw(Site::MmapMap).is_some();
        if !refused {
            if let Some(m) = mm::Mapping::map(&file, len) {
                return Ok(ShardData::Mapped(m));
            }
        }
        match crate::runtime_config::RuntimeConfig::current().store_fallback {
            Some(StoreFallback::Mem) => {
                log::warn!(
                    "mmap refused for {}: loading shard resident (CREST_STORE_FALLBACK=mem)",
                    path.display()
                );
                Ok(ShardData::Resident(Self::read_resident(file, len)?))
            }
            _ => {
                log::warn!("mmap refused for {}: degrading to pread", path.display());
                Ok(ShardData::Pread(file))
            }
        }
    }

    #[cfg(not(unix))]
    fn shard_data(file: File, len: usize, path: &std::path::Path) -> Result<ShardData> {
        let _ = path;
        Ok(ShardData::Resident(Self::read_resident(file, len)?))
    }

    fn read_resident(mut file: File, len: usize) -> Result<Vec<f32>> {
        use std::io::Read;
        let mut bytes = vec![0u8; len];
        file.read_exact(&mut bytes)?;
        let mut vals = vec![0.0f32; len / 4];
        decode_f32le(&bytes, &mut vals);
        Ok(vals)
    }

    /// Rows per shard (the pack-time chunking).
    pub fn shard_rows(&self) -> usize {
        self.shard_rows
    }

    /// Read `rows` rows starting at local row `row0` of shard `s`.
    fn read_shard(&self, s: usize, row0: usize, rows: usize, out: &mut [f32]) {
        let d = self.d;
        let shard = &self.shards[s];
        debug_assert!(row0 + rows <= shard.rows);
        match &shard.data {
            #[cfg(unix)]
            ShardData::Mapped(m) => {
                let bytes = &m.bytes()[row0 * d * 4..(row0 + rows) * d * 4];
                decode_f32le(bytes, &mut out[..rows * d]);
            }
            #[cfg(unix)]
            ShardData::Pread(file) => {
                use std::os::unix::fs::FileExt;
                let mut bytes = vec![0u8; rows * d * 4];
                // `read_exact_at` already retries `Interrupted`; the size
                // was validated at open, so a failure here is real I/O
                // breakage mid-run — fail naming the shard, never hand
                // garbage floats to the trainer
                if let Err(e) = file.read_exact_at(&mut bytes, (row0 * d * 4) as u64) {
                    panic!("shard {}: pread failed mid-run: {e}", shard.path.display());
                }
                decode_f32le(&bytes, &mut out[..rows * d]);
            }
            ShardData::Resident(vals) => {
                out[..rows * d].copy_from_slice(&vals[row0 * d..(row0 + rows) * d]);
            }
        }
    }
}

impl DataStore for MmapStore {
    fn n(&self) -> usize {
        self.n
    }

    fn d(&self) -> usize {
        self.d
    }

    fn kind(&self) -> &'static str {
        "mmap"
    }

    fn read_rows(&self, start: usize, rows: usize, out: &mut [f32]) {
        debug_assert!(start + rows <= self.n);
        // split the block at shard boundaries
        let (d, mut row, mut done) = (self.d, start, 0usize);
        while done < rows {
            let s = row / self.shard_rows;
            let local = row - s * self.shard_rows;
            let take = (self.shard_rows - local).min(rows - done);
            self.read_shard(s, local, take, &mut out[done * d..(done + take) * d]);
            row += take;
            done += take;
        }
    }

    fn gather_into(&self, idx: &[usize], out: &mut [f32]) {
        let d = self.d;
        debug_assert_eq!(out.len(), idx.len() * d);
        // coalesce runs of consecutive indices into one block read per
        // run — epoch-ordered and chunked access patterns touch each
        // shard once instead of once per row
        let mut k = 0;
        while k < idx.len() {
            let start = idx[k];
            let mut run = 1;
            while k + run < idx.len() && idx[k + run] == start + run {
                run += 1;
            }
            self.read_rows(start, run, &mut out[k * d..(k + run) * d]);
            k += run;
        }
    }
}

// ------------------------------------------------- default-store plumbing

/// Which [`DataStore`] backend [`crate::data::prepare_splits`] builds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreKind {
    /// In-RAM features (the default).
    Mem,
    /// Sharded on-disk features, memory-mapped.
    Mmap,
}

impl StoreKind {
    /// Parse a CLI/env value (`mem` | `mmap`).
    pub fn parse(s: &str) -> Result<StoreKind> {
        match s {
            "mem" => Ok(StoreKind::Mem),
            "mmap" => Ok(StoreKind::Mmap),
            other => bail!("unknown data store {other:?} (expected mem|mmap)"),
        }
    }

    /// Canonical name (`"mem"` / `"mmap"`).
    pub fn name(self) -> &'static str {
        match self {
            StoreKind::Mem => "mem",
            StoreKind::Mmap => "mmap",
        }
    }
}

/// Degradation target when the kernel refuses a shard mapping
/// (`CREST_STORE_FALLBACK`). Either rung serves bitwise-identical
/// bytes — the knob trades memory for syscall traffic, never results.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreFallback {
    /// Positional reads on the held fd (the default rung).
    Pread,
    /// Load the affected shard fully resident.
    Mem,
}

impl StoreFallback {
    /// Parse a CLI/env value (`pread` | `mem`).
    pub fn parse(s: &str) -> Result<StoreFallback> {
        match s {
            "pread" => Ok(StoreFallback::Pread),
            "mem" => Ok(StoreFallback::Mem),
            other => bail!("unknown store fallback {other:?} (expected pread|mem)"),
        }
    }

    /// Canonical name (`"pread"` / `"mem"`).
    pub fn name(self) -> &'static str {
        match self {
            StoreFallback::Pread => "pread",
            StoreFallback::Mem => "mem",
        }
    }
}

fn kind_cell() -> &'static RwLock<StoreKind> {
    static KIND: OnceLock<RwLock<StoreKind>> = OnceLock::new();
    KIND.get_or_init(|| {
        RwLock::new(crate::runtime_config::RuntimeConfig::current().resolved_store())
    })
}

/// The process-wide default store backend (`CREST_DATA_STORE` at first
/// use, unless overridden by [`set_default_store`]).
pub fn default_store() -> StoreKind {
    *kind_cell().read().unwrap()
}

/// Override the process-wide default store backend (the `--data-store`
/// flag lands here).
pub fn set_default_store(kind: StoreKind) {
    *kind_cell().write().unwrap() = kind;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: usize, cols: usize) -> MatF32 {
        let data: Vec<f32> = (0..rows * cols).map(|i| i as f32 * 0.5 - 3.0).collect();
        MatF32::from_vec(rows, cols, data).unwrap()
    }

    fn write_shards(x: &MatF32, shard_rows: usize, tag: &str) -> Vec<std::path::PathBuf> {
        let pid = std::process::id();
        let dir = std::env::temp_dir().join(format!("crest_store_test_{pid}_{tag}"));
        std::fs::create_dir_all(&dir).unwrap();
        let mut paths = Vec::new();
        let mut start = 0;
        let mut s = 0;
        while start < x.rows {
            let rows = shard_rows.min(x.rows - start);
            let mut bytes = Vec::with_capacity(rows * x.cols * 4);
            for v in &x.data[start * x.cols..(start + rows) * x.cols] {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
            let p = dir.join(format!("shard_{s:05}.bin"));
            std::fs::write(&p, bytes).unwrap();
            paths.push(p);
            start += rows;
            s += 1;
        }
        paths
    }

    #[test]
    fn mem_and_mmap_serve_identical_bytes() {
        let x = mat(23, 5);
        let paths = write_shards(&x, 7, "ident");
        let mem = MemStore::new(x.clone());
        let mm = MmapStore::open(&paths, 23, 5, 7).unwrap();
        assert_eq!(mm.kind(), "mmap");
        assert_eq!((mm.n(), mm.d()), (23, 5));
        // block reads across shard boundaries
        for &(start, rows) in &[(0usize, 23usize), (5, 10), (6, 1), (20, 3), (0, 7), (7, 7)] {
            let mut a = vec![0.0f32; rows * 5];
            let mut b = vec![0.0f32; rows * 5];
            mem.read_rows(start, rows, &mut a);
            mm.read_rows(start, rows, &mut b);
            assert_eq!(a, b, "block ({start},{rows})");
        }
        // gathers, including runs that coalesce and wrap shards
        let idx = vec![22, 0, 1, 2, 6, 7, 8, 13, 13, 5];
        let mut a = vec![0.0f32; idx.len() * 5];
        let mut b = vec![0.0f32; idx.len() * 5];
        mem.gather_into(&idx, &mut a);
        mm.gather_into(&idx, &mut b);
        assert_eq!(a, b);
        for p in paths {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn open_rejects_wrong_sized_shards() {
        let x = mat(10, 3);
        let paths = write_shards(&x, 4, "badsize");
        // truncate the middle shard
        let bytes = std::fs::read(&paths[1]).unwrap();
        std::fs::write(&paths[1], &bytes[..bytes.len() - 4]).unwrap();
        let err = MmapStore::open(&paths, 10, 3, 4).unwrap_err();
        assert!(format!("{err:#}").contains("expected"), "{err:#}");
        // wrong shard count
        assert!(MmapStore::open(&paths[..2], 10, 3, 4).is_err());
        for p in paths {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn store_kind_parse_roundtrip() {
        assert_eq!(StoreKind::parse("mem").unwrap(), StoreKind::Mem);
        assert_eq!(StoreKind::parse("mmap").unwrap(), StoreKind::Mmap);
        assert!(StoreKind::parse("tape").is_err());
        assert_eq!(StoreKind::Mmap.name(), "mmap");
    }
}
