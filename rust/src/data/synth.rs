//! Synthetic class-conditional Gaussian-mixture corpora — the dataset
//! proxies of the paper's benchmarks.
//!
//! The generator exposes the three axes coreset selection is sensitive to:
//!
//! * **redundancy** — a fraction of the mass is drawn tightly around a few
//!   dominant sub-clusters per class (many near-duplicate easy examples,
//!   the "10% you don't need" of Birodkar et al.);
//! * **difficulty spectrum** — the rest is drawn with a larger spread so
//!   margins vary continuously (drives the forgettability ordering of
//!   paper Fig. 5);
//! * **label noise** — a fraction of labels are flipped (hard/never-learned
//!   tail).
//!
//! Per-example ground truth (difficulty, noise flag, cluster id) is kept as
//! metadata for the analysis benches.
//!
//! Two emission paths share one row generator ([`Synth`]): [`generate`]
//! materializes the corpus in RAM, and [`generate_packed`] streams it
//! straight into the sharded on-disk format so the ≥10^6-example scaling
//! corpora never have to be resident. Both consume the RNG streams in the
//! same order and normalize with the same f64 accumulation sequence, so
//! packing a generated corpus and streaming one are bitwise identical —
//! the mem-vs-mmap determinism tests rely on this.

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

use crate::data::dataset::{Dataset, Splits};
use crate::data::shard::{self, shard_file, PackMeta, SplitWriter};
use crate::data::store::decode_f32le;
use crate::tensor::MatF32;
use crate::util::rng::Rng;

/// Generation parameters for one corpus.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    /// Variant name the spec mirrors.
    pub name: &'static str,
    /// Training examples.
    pub n_train: usize,
    /// Validation examples.
    pub n_val: usize,
    /// Test examples.
    pub n_test: usize,
    /// Feature dimensionality.
    pub d: usize,
    /// Number of classes.
    pub classes: usize,
    /// Sub-clusters per class (redundancy structure).
    pub clusters_per_class: usize,
    /// Fraction of examples drawn from the tight "easy" component.
    pub redundancy: f32,
    /// Label flip probability.
    pub label_noise: f32,
    /// Separation of class centers (bigger = easier problem).
    pub margin: f32,
    /// Spread of easy examples around their sub-cluster center.
    pub easy_sigma: f32,
    /// Spread of hard examples.
    pub hard_sigma: f32,
    /// Generation seed (independent of the training seed streams).
    pub seed: u64,
}

impl SynthSpec {
    /// Preset mirroring a paper dataset. The four
    /// variants differ in size, dimensionality, class count and hardness
    /// the way CIFAR-10 → CIFAR-100 → TinyImageNet → SNLI do.
    pub fn preset(variant: &str, seed: u64) -> Option<SynthSpec> {
        let s = match variant {
            "cifar10-proxy" => SynthSpec {
                name: "cifar10-proxy",
                n_train: 5120,
                n_val: 512,
                n_test: 1024,
                d: 64,
                classes: 10,
                clusters_per_class: 3,
                redundancy: 0.85,
                label_noise: 0.01,
                margin: 1.2,
                easy_sigma: 0.4,
                hard_sigma: 2.1,
                seed,
            },
            "cifar100-proxy" => SynthSpec {
                name: "cifar100-proxy",
                n_train: 6400,
                n_val: 512,
                n_test: 1024,
                d: 96,
                classes: 20,
                clusters_per_class: 2,
                redundancy: 0.50,
                label_noise: 0.01,
                margin: 1.7,
                easy_sigma: 0.45,
                hard_sigma: 2.2,
                seed,
            },
            "tinyimagenet-proxy" => SynthSpec {
                name: "tinyimagenet-proxy",
                n_train: 8192,
                n_val: 512,
                n_test: 1024,
                d: 128,
                classes: 40,
                clusters_per_class: 2,
                redundancy: 0.45,
                label_noise: 0.01,
                margin: 1.5,
                easy_sigma: 0.5,
                hard_sigma: 2.3,
                seed,
            },
            "snli-proxy" => SynthSpec {
                name: "snli-proxy",
                n_train: 20480,
                n_val: 1024,
                n_test: 2048,
                d: 96,
                classes: 3,
                clusters_per_class: 8,
                redundancy: 0.6,
                label_noise: 0.01,
                margin: 1.6,
                easy_sigma: 0.5,
                hard_sigma: 2.2,
                seed,
            },
            // Tiny corpus for fast tests: mirrors the `smoke` ModelSpec
            // (d_in=16, 4 classes) at a size where full experiment cells run
            // in well under a second even in debug builds.
            "smoke" => SynthSpec {
                name: "smoke",
                n_train: 1024,
                n_val: 128,
                n_test: 256,
                d: 16,
                classes: 4,
                clusters_per_class: 2,
                redundancy: 0.7,
                label_noise: 0.02,
                margin: 1.5,
                easy_sigma: 0.4,
                hard_sigma: 2.0,
                seed,
            },
            _ => return None,
        };
        Some(s)
    }
}

/// One generated example's labels and provenance.
struct RowMeta {
    y: i32,
    difficulty: f32,
    is_noisy: bool,
    cluster: u32,
}

/// The shared row generator: cluster geometry plus the generation RNG.
///
/// Geometry: a "Gaussian checkerboard". Sub-cluster centers are scattered
/// i.i.d. in a low-dimensional latent subspace (dimension grows with the
/// cluster count) and classes are assigned round-robin, so same-class
/// regions are *not* contiguous — the model must carve one decision region
/// per sub-cluster. That is what makes convergence take many epochs
/// (one-blob-per-class mixtures are fit by an MLP in a few hundred steps)
/// while keeping the redundancy/difficulty structure coresets exploit.
struct Synth {
    spec: SynthSpec,
    sub: MatF32,
    latent: usize,
    n_clusters: usize,
    rng: Rng,
}

impl Synth {
    fn new(spec: &SynthSpec) -> Synth {
        let mut rng = Rng::new(spec.seed ^ 0xC0FF_EE00);
        let k = spec.clusters_per_class;
        let n_clusters = spec.classes * k;
        // latent subspace dimension: enough to scatter clusters, far below d
        let latent = ((n_clusters as f32).log2() as usize + 3).min(spec.d);
        let mut sub = MatF32::zeros(n_clusters, spec.d);
        for cl in 0..n_clusters {
            let row = sub.row_mut(cl);
            for v in row.iter_mut().take(latent) {
                *v = rng.normal() * spec.margin * 2.0;
            }
            // tiny off-subspace jitter keeps the embedding full-rank
            for v in row.iter_mut().skip(latent) {
                *v = rng.normal() * 0.01;
            }
        }
        Synth { spec: spec.clone(), sub, latent, n_clusters, rng }
    }

    /// Emit the next example's (un-normalized) features into `row`.
    fn gen_row(&mut self, row: &mut [f32]) -> RowMeta {
        let spec = &self.spec;
        // round-robin label assignment over scattered clusters
        let cl = self.rng.gen_range(self.n_clusters);
        let c = cl % spec.classes;
        let easy = self.rng.uniform() < spec.redundancy;
        let sigma = if easy { spec.easy_sigma } else { spec.hard_sigma };
        let center = self.sub.row(cl);
        let mut dist2 = 0.0f32;
        // displacement lives in the latent subspace (plus tiny ambient
        // noise) so "hard" means near a *different* cluster's region
        for (j, (o, &b)) in row.iter_mut().zip(center).enumerate() {
            let scale = if j < self.latent { sigma } else { 0.05 };
            let noise = self.rng.normal() * scale;
            *o = b + noise;
            dist2 += noise * noise;
        }
        // difficulty: displacement relative to cluster spacing, in [0,1)
        let rel = dist2.sqrt() / (spec.margin * 2.0 * (self.latent as f32).sqrt());
        let mut difficulty = rel / (1.0 + rel);
        let mut label = c;
        let mut is_noisy = false;
        if self.rng.uniform() < spec.label_noise {
            label = (c + 1 + self.rng.gen_range(spec.classes - 1)) % spec.classes;
            is_noisy = true;
            difficulty = 1.0; // mislabeled = unlearnable without memorizing
        }
        RowMeta { y: label as i32, difficulty, is_noisy, cluster: cl as u32 }
    }

    fn gen_split(&mut self, n: usize) -> Dataset {
        let spec_d = self.spec.d;
        let classes = self.spec.classes;
        let mut x = MatF32::zeros(n, spec_d);
        let mut y = vec![0i32; n];
        let mut difficulty = vec![0.0f32; n];
        let mut is_noisy = vec![false; n];
        let mut cluster = vec![0u32; n];
        for i in 0..n {
            let m = self.gen_row(x.row_mut(i));
            y[i] = m.y;
            difficulty[i] = m.difficulty;
            is_noisy[i] = m.is_noisy;
            cluster[i] = m.cluster;
        }
        normalize_features(&mut x);
        Dataset::from_mat(x, y, classes, difficulty, is_noisy, cluster)
    }
}

/// Generate the train/val/test splits for a spec, resident in RAM.
pub fn generate(spec: &SynthSpec) -> Splits {
    let mut g = Synth::new(spec);
    let train = g.gen_split(spec.n_train);
    let val = g.gen_split(spec.n_val);
    let test = g.gen_split(spec.n_test);
    Splits { train, val, test }
}

/// Standardize features to zero mean / unit variance per dimension
/// (computed on the split itself — proxy for the usual dataset transform).
fn normalize_features(x: &mut MatF32) {
    let (n, d) = (x.rows, x.cols);
    if n == 0 {
        return;
    }
    for j in 0..d {
        let mut mean = 0.0f64;
        for i in 0..n {
            mean += x.row(i)[j] as f64;
        }
        mean /= n as f64;
        let mut var = 0.0f64;
        for i in 0..n {
            let v = x.row(i)[j] as f64 - mean;
            var += v * v;
        }
        var /= n as f64;
        let inv = 1.0 / var.sqrt().max(1e-6);
        for i in 0..n {
            let v = &mut x.row_mut(i)[j];
            *v = ((*v as f64 - mean) * inv) as f32;
        }
    }
}

static PACK_TMP: AtomicU64 = AtomicU64::new(0);

/// Generate a corpus directly into the sharded on-disk format at `root`
/// (`root/train` etc.) without ever materializing a split in RAM.
///
/// Three streaming passes per split replicate [`normalize_features`]
/// exactly: generation accumulates the per-dimension f64 mean sums in row
/// order (the same addition sequence per accumulator as the resident
/// j-outer loop), a read-back pass accumulates variances against those
/// means, and a rewrite pass normalizes each shard in place. The result
/// is bitwise identical to `pack_splits(&generate(spec), root, …)`.
///
/// Publication is atomic: everything is written to a sibling temp
/// directory and `rename`d onto `root`, so concurrent callers (the sweep
/// orchestrator packs lazily) either win the rename or find a complete
/// pack already in place — never a torn one.
pub fn generate_packed(spec: &SynthSpec, root: &Path, shard_rows: usize) -> Result<()> {
    if shard.is_packed(root) {
        return Ok(());
    }
    let parent = root.parent().unwrap_or_else(|| Path::new("."));
    std::fs::create_dir_all(parent)?;
    let stamp = PACK_TMP.fetch_add(1, Ordering::Relaxed);
    let base = root.file_name().and_then(|s| s.to_str()).unwrap_or("pack");
    let tmp = parent.join(format!(".tmp-{base}-{}-{stamp}", std::process::id()));
    let _ = std::fs::remove_dir_all(&tmp);

    let result = (|| -> Result<()> {
        let mut g = Synth::new(spec);
        for (name, n) in [("train", spec.n_train), ("val", spec.n_val), ("test", spec.n_test)] {
            stream_split(&mut g, n, &tmp.join(name), shard_rows)
                .with_context(|| format!("packing split {name}"))?;
        }
        Ok(())
    })();
    if let Err(e) = result {
        let _ = std::fs::remove_dir_all(&tmp);
        return Err(e);
    }

    match std::fs::rename(&tmp, root) {
        Ok(()) => {
            // land the rename itself: a crash right after this point must
            // not roll the directory entry back to the temp name
            crate::util::artifact_io::sync_parent(root);
            Ok(())
        }
        Err(e) => {
            let _ = std::fs::remove_dir_all(&tmp);
            if shard.is_packed(root) {
                // a concurrent packer published first; its output is
                // bitwise identical, so just use it
                Ok(())
            } else {
                Err(e).with_context(|| format!("publishing pack at {root:?}"))
            }
        }
    }
}

/// Stream one split to disk: generate + accumulate means, then normalize
/// the raw shards in place.
fn stream_split(g: &mut Synth, n: usize, dir: &Path, shard_rows: usize) -> Result<()> {
    let d = g.spec.d;
    let mut w = SplitWriter::create(dir, n, d, g.spec.classes, shard_rows)?;
    let mut row = vec![0.0f32; d];
    let mut mean = vec![0.0f64; d];
    for _ in 0..n {
        let m = g.gen_row(&mut row);
        for (s, &v) in mean.iter_mut().zip(&row) {
            *s += v as f64;
        }
        w.push_row(&row, m.y, m.difficulty, m.is_noisy, m.cluster)?;
    }
    let meta = w.finish()?;
    if n == 0 {
        return Ok(());
    }
    for s in mean.iter_mut() {
        *s /= n as f64;
    }
    normalize_shards(dir, &meta, &mean)
}

/// Second and third normalization passes over a split's raw shards.
fn normalize_shards(dir: &Path, meta: &PackMeta, mean: &[f64]) -> Result<()> {
    let (n, d) = (meta.n, meta.d);
    let mut var = vec![0.0f64; d];
    let mut buf: Vec<f32> = Vec::new();
    for s in 0..meta.n_shards {
        read_shard_f32(&dir.join(shard_file(s)), &mut buf)?;
        for row in buf.chunks_exact(d) {
            for j in 0..d {
                let v = row[j] as f64 - mean[j];
                var[j] += v * v;
            }
        }
    }
    let inv: Vec<f64> = var.iter().map(|&v| 1.0 / (v / n as f64).sqrt().max(1e-6)).collect();
    let mut bytes: Vec<u8> = Vec::new();
    for s in 0..meta.n_shards {
        let path = dir.join(shard_file(s));
        read_shard_f32(&path, &mut buf)?;
        bytes.clear();
        bytes.reserve(buf.len() * 4);
        for (k, &v) in buf.iter().enumerate() {
            let j = k % d;
            let norm = ((v as f64 - mean[j]) * inv[j]) as f32;
            bytes.extend_from_slice(&norm.to_le_bytes());
        }
        std::fs::write(&path, &bytes).with_context(|| format!("rewrite {path:?}"))?;
    }
    Ok(())
}

fn read_shard_f32(path: &Path, out: &mut Vec<f32>) -> Result<()> {
    let raw = std::fs::read(path).with_context(|| format!("read {path:?}"))?;
    if raw.len() % 4 != 0 {
        bail!("{path:?}: length {} is not a whole number of f32s", raw.len());
    }
    out.resize(raw.len() / 4, 0.0);
    decode_f32le(&raw, out);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> SynthSpec {
        SynthSpec {
            name: "test",
            n_train: 400,
            n_val: 50,
            n_test: 50,
            d: 16,
            classes: 4,
            clusters_per_class: 2,
            redundancy: 0.5,
            label_noise: 0.1,
            margin: 3.0,
            easy_sigma: 0.3,
            hard_sigma: 1.5,
            seed: 1,
        }
    }

    #[test]
    fn shapes_and_ranges() {
        let s = generate(&small_spec());
        assert_eq!(s.train.n(), 400);
        assert_eq!(s.val.n(), 50);
        assert_eq!(s.test.n(), 50);
        assert_eq!(s.train.d(), 16);
        assert!(s.train.y.iter().all(|&y| (0..4).contains(&(y as usize))));
        assert!(s.train.difficulty.iter().all(|&d| (0.0..=1.0).contains(&d)));
    }

    #[test]
    fn deterministic_by_seed() {
        let a = generate(&small_spec());
        let b = generate(&small_spec());
        assert_eq!(a.train.to_mat().data, b.train.to_mat().data);
        assert_eq!(a.train.y, b.train.y);
        let mut spec2 = small_spec();
        spec2.seed = 2;
        let c = generate(&spec2);
        assert_ne!(a.train.to_mat().data, c.train.to_mat().data);
    }

    #[test]
    fn noise_rate_near_target() {
        let s = generate(&small_spec());
        let noisy = s.train.is_noisy.iter().filter(|&&b| b).count();
        let rate = noisy as f32 / 400.0;
        assert!((0.04..0.20).contains(&rate), "rate {rate}");
    }

    #[test]
    fn features_standardized() {
        let s = generate(&small_spec());
        let x = s.train.to_mat();
        for j in [0, 7, 15] {
            let col: Vec<f32> = (0..x.rows).map(|i| x.row(i)[j]).collect();
            assert!(crate::util::stats::mean(&col).abs() < 0.05);
            assert!((crate::util::stats::variance(&col) - 1.0).abs() < 0.1);
        }
    }

    #[test]
    fn noisy_examples_marked_hardest() {
        let s = generate(&small_spec());
        for i in 0..s.train.n() {
            if s.train.is_noisy[i] {
                assert_eq!(s.train.difficulty[i], 1.0);
            }
        }
    }

    #[test]
    fn all_presets_exist_and_generate() {
        for v in ["cifar10-proxy", "cifar100-proxy", "tinyimagenet-proxy", "snli-proxy"] {
            let spec = SynthSpec::preset(v, 0).unwrap();
            assert_eq!(spec.name, v);
        }
        assert!(SynthSpec::preset("bogus", 0).is_none());
    }

    #[test]
    fn classes_roughly_balanced() {
        let s = generate(&small_spec());
        for c in s.train.class_counts() {
            assert!((50..150).contains(&c), "count {c}");
        }
    }

    #[test]
    fn streaming_pack_matches_in_memory_pack_bitwise() {
        let spec = small_spec();
        let base = std::env::temp_dir()
            .join(format!("crest_synth_stream_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let streamed = base.join("streamed");
        let packed = base.join("packed");
        // shard_rows=96 leaves a short tail shard on the train split
        generate_packed(&spec, &streamed, 96).unwrap();
        shard::pack_splits(&generate(&spec), &packed, 96).unwrap();
        for split in ["train", "val", "test"] {
            let (a, b) = (streamed.join(split), packed.join(split));
            let meta = PackMeta::load(&a).unwrap();
            assert_eq!(meta, PackMeta::load(&b).unwrap());
            let mut files: Vec<String> = (0..meta.n_shards).map(shard_file).collect();
            files.push("labels.bin".into());
            for f in files {
                let fa = std::fs::read(a.join(&f)).unwrap();
                let fb = std::fs::read(b.join(&f)).unwrap();
                assert_eq!(fa, fb, "split {split} file {f} differs");
            }
        }
        // idempotent: an existing complete pack short-circuits
        generate_packed(&spec, &streamed, 96).unwrap();
        std::fs::remove_dir_all(&base).ok();
    }
}
