//! Synthetic class-conditional Gaussian-mixture corpora — the dataset
//! proxies of the paper's benchmarks.
//!
//! The generator exposes the three axes coreset selection is sensitive to:
//!
//! * **redundancy** — a fraction of the mass is drawn tightly around a few
//!   dominant sub-clusters per class (many near-duplicate easy examples,
//!   the "10% you don't need" of Birodkar et al.);
//! * **difficulty spectrum** — the rest is drawn with a larger spread so
//!   margins vary continuously (drives the forgettability ordering of
//!   paper Fig. 5);
//! * **label noise** — a fraction of labels are flipped (hard/never-learned
//!   tail).
//!
//! Per-example ground truth (difficulty, noise flag, cluster id) is kept as
//! metadata for the analysis benches.

use crate::data::dataset::{Dataset, Splits};
use crate::tensor::MatF32;
use crate::util::rng::Rng;

/// Generation parameters for one corpus.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    /// Variant name the spec mirrors.
    pub name: &'static str,
    /// Training examples.
    pub n_train: usize,
    /// Validation examples.
    pub n_val: usize,
    /// Test examples.
    pub n_test: usize,
    /// Feature dimensionality.
    pub d: usize,
    /// Number of classes.
    pub classes: usize,
    /// Sub-clusters per class (redundancy structure).
    pub clusters_per_class: usize,
    /// Fraction of examples drawn from the tight "easy" component.
    pub redundancy: f32,
    /// Label flip probability.
    pub label_noise: f32,
    /// Separation of class centers (bigger = easier problem).
    pub margin: f32,
    /// Spread of easy examples around their sub-cluster center.
    pub easy_sigma: f32,
    /// Spread of hard examples.
    pub hard_sigma: f32,
    /// Generation seed (independent of the training seed streams).
    pub seed: u64,
}

impl SynthSpec {
    /// Preset mirroring a paper dataset. The four
    /// variants differ in size, dimensionality, class count and hardness
    /// the way CIFAR-10 → CIFAR-100 → TinyImageNet → SNLI do.
    pub fn preset(variant: &str, seed: u64) -> Option<SynthSpec> {
        let s = match variant {
            "cifar10-proxy" => SynthSpec {
                name: "cifar10-proxy",
                n_train: 5120,
                n_val: 512,
                n_test: 1024,
                d: 64,
                classes: 10,
                clusters_per_class: 3,
                redundancy: 0.85,
                label_noise: 0.01,
                margin: 1.2,
                easy_sigma: 0.4,
                hard_sigma: 2.1,
                seed,
            },
            "cifar100-proxy" => SynthSpec {
                name: "cifar100-proxy",
                n_train: 6400,
                n_val: 512,
                n_test: 1024,
                d: 96,
                classes: 20,
                clusters_per_class: 2,
                redundancy: 0.50,
                label_noise: 0.01,
                margin: 1.7,
                easy_sigma: 0.45,
                hard_sigma: 2.2,
                seed,
            },
            "tinyimagenet-proxy" => SynthSpec {
                name: "tinyimagenet-proxy",
                n_train: 8192,
                n_val: 512,
                n_test: 1024,
                d: 128,
                classes: 40,
                clusters_per_class: 2,
                redundancy: 0.45,
                label_noise: 0.01,
                margin: 1.5,
                easy_sigma: 0.5,
                hard_sigma: 2.3,
                seed,
            },
            "snli-proxy" => SynthSpec {
                name: "snli-proxy",
                n_train: 20480,
                n_val: 1024,
                n_test: 2048,
                d: 96,
                classes: 3,
                clusters_per_class: 8,
                redundancy: 0.6,
                label_noise: 0.01,
                margin: 1.6,
                easy_sigma: 0.5,
                hard_sigma: 2.2,
                seed,
            },
            // Tiny corpus for fast tests: mirrors the `smoke` ModelSpec
            // (d_in=16, 4 classes) at a size where full experiment cells run
            // in well under a second even in debug builds.
            "smoke" => SynthSpec {
                name: "smoke",
                n_train: 1024,
                n_val: 128,
                n_test: 256,
                d: 16,
                classes: 4,
                clusters_per_class: 2,
                redundancy: 0.7,
                label_noise: 0.02,
                margin: 1.5,
                easy_sigma: 0.4,
                hard_sigma: 2.0,
                seed,
            },
            _ => return None,
        };
        Some(s)
    }
}

/// Generate the train/val/test splits for a spec.
///
/// Geometry: a "Gaussian checkerboard". Sub-cluster centers are scattered
/// i.i.d. in a low-dimensional latent subspace (dimension grows with the
/// cluster count) and classes are assigned round-robin, so same-class
/// regions are *not* contiguous — the model must carve one decision region
/// per sub-cluster. That is what makes convergence take many epochs
/// (one-blob-per-class mixtures are fit by an MLP in a few hundred steps)
/// while keeping the redundancy/difficulty structure coresets exploit.
pub fn generate(spec: &SynthSpec) -> Splits {
    let mut rng = Rng::new(spec.seed ^ 0xC0FF_EE00);
    let k = spec.clusters_per_class;
    let n_clusters = spec.classes * k;
    // latent subspace dimension: enough to scatter clusters, far below d
    let latent = ((n_clusters as f32).log2() as usize + 3).min(spec.d);
    let mut sub = MatF32::zeros(n_clusters, spec.d);
    for cl in 0..n_clusters {
        let row = sub.row_mut(cl);
        for v in row.iter_mut().take(latent) {
            *v = rng.normal() * spec.margin * 2.0;
        }
        // tiny off-subspace jitter keeps the embedding full-rank
        for v in row.iter_mut().skip(latent) {
            *v = rng.normal() * 0.01;
        }
    }

    let gen_split = |n: usize, rng: &mut Rng| -> Dataset {
        let mut x = MatF32::zeros(n, spec.d);
        let mut y = vec![0i32; n];
        let mut difficulty = vec![0.0f32; n];
        let mut is_noisy = vec![false; n];
        let mut cluster = vec![0u32; n];
        for i in 0..n {
            // round-robin label assignment over scattered clusters
            let cl = rng.gen_range(n_clusters);
            let c = cl % spec.classes;
            let easy = rng.uniform() < spec.redundancy;
            let sigma = if easy { spec.easy_sigma } else { spec.hard_sigma };
            let center = sub.row(cl).to_vec();
            let row = x.row_mut(i);
            let mut dist2 = 0.0f32;
            // displacement lives in the latent subspace (plus tiny ambient
            // noise) so "hard" means near a *different* cluster's region
            for (j, (o, &b)) in row.iter_mut().zip(&center).enumerate() {
                let scale = if j < latent { sigma } else { 0.05 };
                let noise = rng.normal() * scale;
                *o = b + noise;
                dist2 += noise * noise;
            }
            // difficulty: displacement relative to cluster spacing, in [0,1)
            let rel = dist2.sqrt() / (spec.margin * 2.0 * (latent as f32).sqrt());
            difficulty[i] = rel / (1.0 + rel);
            let mut label = c;
            if rng.uniform() < spec.label_noise {
                label = (c + 1 + rng.gen_range(spec.classes - 1)) % spec.classes;
                is_noisy[i] = true;
                difficulty[i] = 1.0; // mislabeled = unlearnable without memorizing
            }
            y[i] = label as i32;
            cluster[i] = cl as u32;
        }
        normalize_features(&mut x);
        Dataset { x, y, classes: spec.classes, difficulty, is_noisy, cluster }
    };

    let train = gen_split(spec.n_train, &mut rng);
    let val = gen_split(spec.n_val, &mut rng);
    let test = gen_split(spec.n_test, &mut rng);
    Splits { train, val, test }
}

/// Standardize features to zero mean / unit variance per dimension
/// (computed on the split itself — proxy for the usual dataset transform).
fn normalize_features(x: &mut MatF32) {
    let (n, d) = (x.rows, x.cols);
    if n == 0 {
        return;
    }
    for j in 0..d {
        let mut mean = 0.0f64;
        for i in 0..n {
            mean += x.row(i)[j] as f64;
        }
        mean /= n as f64;
        let mut var = 0.0f64;
        for i in 0..n {
            let v = x.row(i)[j] as f64 - mean;
            var += v * v;
        }
        var /= n as f64;
        let inv = 1.0 / var.sqrt().max(1e-6);
        for i in 0..n {
            let v = &mut x.row_mut(i)[j];
            *v = ((*v as f64 - mean) * inv) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> SynthSpec {
        SynthSpec {
            name: "test",
            n_train: 400,
            n_val: 50,
            n_test: 50,
            d: 16,
            classes: 4,
            clusters_per_class: 2,
            redundancy: 0.5,
            label_noise: 0.1,
            margin: 3.0,
            easy_sigma: 0.3,
            hard_sigma: 1.5,
            seed: 1,
        }
    }

    #[test]
    fn shapes_and_ranges() {
        let s = generate(&small_spec());
        assert_eq!(s.train.n(), 400);
        assert_eq!(s.val.n(), 50);
        assert_eq!(s.test.n(), 50);
        assert_eq!(s.train.d(), 16);
        assert!(s.train.y.iter().all(|&y| (0..4).contains(&(y as usize))));
        assert!(s.train.difficulty.iter().all(|&d| (0.0..=1.0).contains(&d)));
    }

    #[test]
    fn deterministic_by_seed() {
        let a = generate(&small_spec());
        let b = generate(&small_spec());
        assert_eq!(a.train.x.data, b.train.x.data);
        assert_eq!(a.train.y, b.train.y);
        let mut spec2 = small_spec();
        spec2.seed = 2;
        let c = generate(&spec2);
        assert_ne!(a.train.x.data, c.train.x.data);
    }

    #[test]
    fn noise_rate_near_target() {
        let s = generate(&small_spec());
        let noisy = s.train.is_noisy.iter().filter(|&&b| b).count();
        let rate = noisy as f32 / 400.0;
        assert!((0.04..0.20).contains(&rate), "rate {rate}");
    }

    #[test]
    fn features_standardized() {
        let s = generate(&small_spec());
        let x = &s.train.x;
        for j in [0, 7, 15] {
            let col: Vec<f32> = (0..x.rows).map(|i| x.row(i)[j]).collect();
            assert!(crate::util::stats::mean(&col).abs() < 0.05);
            assert!((crate::util::stats::variance(&col) - 1.0).abs() < 0.1);
        }
    }

    #[test]
    fn noisy_examples_marked_hardest() {
        let s = generate(&small_spec());
        for i in 0..s.train.n() {
            if s.train.is_noisy[i] {
                assert_eq!(s.train.difficulty[i], 1.0);
            }
        }
    }

    #[test]
    fn all_presets_exist_and_generate() {
        for v in ["cifar10-proxy", "cifar100-proxy", "tinyimagenet-proxy", "snli-proxy"] {
            let spec = SynthSpec::preset(v, 0).unwrap();
            assert_eq!(spec.name, v);
        }
        assert!(SynthSpec::preset("bogus", 0).is_none());
    }

    #[test]
    fn classes_roughly_balanced() {
        let s = generate(&small_spec());
        for c in s.train.class_counts() {
            assert!((50..150).contains(&c), "count {c}");
        }
    }
}
