//! Prefetching batch pipeline with bounded-channel backpressure.
//!
//! Host-side batch assembly (row gathers + label copies) overlaps with XLA
//! execution: a worker thread materializes upcoming batches into a bounded
//! channel while the trainer consumes them. Batch assembly goes through
//! `Dataset::batch`, so the worker reads blocks from whichever store backs
//! the split — with the mmap store this is what overlaps shard I/O with
//! compute. Selection methods that choose their own indices use
//! `Dataset::batch` directly instead.

use std::sync::mpsc::{sync_channel, Receiver};
use std::thread::JoinHandle;

use crate::data::dataset::Dataset;
use crate::tensor::MatF32;
use crate::util::rng::Rng;

/// One assembled training batch.
#[derive(Debug)]
pub struct Batch {
    /// Source example indices (for loss/forgettability bookkeeping).
    pub idx: Vec<usize>,
    /// Batch features.
    pub x: MatF32,
    /// Batch labels.
    pub y: Vec<i32>,
}

/// Epoch-shuffled prefetching loader over a dataset.
pub struct Loader {
    rx: Option<Receiver<Batch>>,
    handle: Option<JoinHandle<()>>,
}

impl Loader {
    /// Stream `total_batches` batches of size `m`, reshuffling each epoch.
    /// `depth` bounds how many batches may be in flight (backpressure).
    /// The index stream depends only on `seed`, never on `depth`.
    pub fn spawn(ds: &Dataset, m: usize, total_batches: usize, seed: u64, depth: usize) -> Loader {
        assert!(m <= ds.n(), "batch {} > dataset {}", m, ds.n());
        let ds = ds.clone(); // shallow: the feature store is behind an Arc
        let (tx, rx) = sync_channel(depth.max(1));
        let handle = std::thread::spawn(move || {
            let mut rng = Rng::new(seed);
            let mut order: Vec<usize> = (0..ds.n()).collect();
            let mut cursor = ds.n(); // force shuffle on first use
            for _ in 0..total_batches {
                if cursor + m > ds.n() {
                    rng.shuffle(&mut order);
                    cursor = 0;
                }
                let idx: Vec<usize> = order[cursor..cursor + m].to_vec();
                cursor += m;
                let (x, y) = ds.batch(&idx);
                if tx.send(Batch { idx, x, y }).is_err() {
                    return; // consumer dropped early
                }
            }
        });
        Loader { rx: Some(rx), handle: Some(handle) }
    }

    /// Blocking next; `None` when the planned stream is exhausted.
    pub fn next(&mut self) -> Option<Batch> {
        self.rx.as_ref()?.recv().ok()
    }
}

impl Drop for Loader {
    fn drop(&mut self) {
        // Close the channel first so the worker's next send fails and it
        // exits, then join so worker panics surface here instead of being
        // silently detached (and so no worker outlives process teardown).
        self.rx.take();
        if let Some(h) = self.handle.take() {
            if let Err(panic) = h.join() {
                if !std::thread::panicking() {
                    std::panic::resume_unwind(panic);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};

    fn ds() -> Dataset {
        generate(&SynthSpec {
            name: "t",
            n_train: 100,
            n_val: 10,
            n_test: 10,
            d: 4,
            classes: 2,
            clusters_per_class: 1,
            redundancy: 0.5,
            label_noise: 0.0,
            margin: 2.0,
            easy_sigma: 0.3,
            hard_sigma: 1.0,
            seed: 5,
        })
        .train
    }

    #[test]
    fn yields_exact_count_and_shapes() {
        let d = ds();
        let mut l = Loader::spawn(&d, 16, 10, 1, 2);
        let mut count = 0;
        while let Some(b) = l.next() {
            assert_eq!(b.idx.len(), 16);
            assert_eq!(b.x.rows, 16);
            assert_eq!(b.y.len(), 16);
            count += 1;
        }
        assert_eq!(count, 10);
    }

    #[test]
    fn epoch_covers_all_examples_without_replacement() {
        let d = ds();
        // 100 examples / batch 20 -> 5 batches per epoch
        let mut l = Loader::spawn(&d, 20, 5, 2, 2);
        let mut seen = std::collections::HashSet::new();
        while let Some(b) = l.next() {
            for i in b.idx {
                assert!(seen.insert(i), "duplicate {i} within epoch");
            }
        }
        assert_eq!(seen.len(), 100);
    }

    #[test]
    fn reshuffles_between_epochs() {
        let d = ds();
        let mut l = Loader::spawn(&d, 100, 2, 3, 2);
        let a = l.next().unwrap().idx;
        let b = l.next().unwrap().idx;
        assert_ne!(a, b, "two epochs should have different order");
    }

    #[test]
    fn batch_content_matches_dataset() {
        let d = ds();
        let mut l = Loader::spawn(&d, 8, 1, 4, 1);
        let b = l.next().unwrap();
        let (want_x, want_y) = d.batch(&b.idx);
        assert_eq!(b.x.data, want_x.data);
        assert_eq!(b.y, want_y);
    }

    #[test]
    fn early_drop_joins_worker_without_hanging() {
        let d = ds();
        // depth 1 keeps the worker blocked mid-send at drop time; deeper
        // channels exercise the drained/partially-drained paths
        for depth in [1, 2, 8] {
            let mut l = Loader::spawn(&d, 16, 1000, 5, depth);
            if depth > 1 {
                let _ = l.next(); // consume one, then abandon the rest
            }
            drop(l); // Drop must close the channel, then join the worker
        }
    }

    #[test]
    fn index_stream_ignores_channel_depth() {
        let d = ds();
        let drain = |depth: usize| -> Vec<Vec<usize>> {
            let mut l = Loader::spawn(&d, 10, 25, 9, depth);
            let mut out = Vec::new();
            while let Some(b) = l.next() {
                out.push(b.idx);
            }
            out
        };
        let base = drain(1);
        assert_eq!(base.len(), 25);
        for depth in [2, 4, 16] {
            assert_eq!(base, drain(depth), "depth {depth} perturbed the stream");
        }
    }
}
