//! Prefetching batch pipeline with bounded-channel backpressure.
//!
//! Host-side batch assembly (row gathers + label copies) overlaps with XLA
//! execution: a worker thread materializes upcoming batches into a bounded
//! channel while the trainer consumes them. This is the streaming-pipeline
//! substrate of the coordinator; selection methods that
//! choose their own indices use `Dataset::batch` directly instead.

use std::sync::mpsc::{sync_channel, Receiver};
use std::thread::JoinHandle;

use crate::data::dataset::Dataset;
use crate::tensor::MatF32;
use crate::util::rng::Rng;

/// One assembled training batch.
#[derive(Debug)]
pub struct Batch {
    /// Source example indices (for loss/forgettability bookkeeping).
    pub idx: Vec<usize>,
    /// Batch features.
    pub x: MatF32,
    /// Batch labels.
    pub y: Vec<i32>,
}

/// Epoch-shuffled prefetching loader over a dataset.
pub struct Loader {
    rx: Receiver<Batch>,
    handle: Option<JoinHandle<()>>,
}

impl Loader {
    /// Stream `total_batches` batches of size `m`, reshuffling each epoch.
    /// `depth` bounds how many batches may be in flight (backpressure).
    pub fn spawn(ds: &Dataset, m: usize, total_batches: usize, seed: u64, depth: usize) -> Loader {
        assert!(m <= ds.n(), "batch {} > dataset {}", m, ds.n());
        let ds = ds.clone();
        let (tx, rx) = sync_channel(depth.max(1));
        let handle = std::thread::spawn(move || {
            let mut rng = Rng::new(seed);
            let mut order: Vec<usize> = (0..ds.n()).collect();
            let mut cursor = ds.n(); // force shuffle on first use
            for _ in 0..total_batches {
                if cursor + m > ds.n() {
                    rng.shuffle(&mut order);
                    cursor = 0;
                }
                let idx: Vec<usize> = order[cursor..cursor + m].to_vec();
                cursor += m;
                let (x, y) = ds.batch(&idx);
                if tx.send(Batch { idx, x, y }).is_err() {
                    return; // consumer dropped early
                }
            }
        });
        Loader { rx, handle: Some(handle) }
    }

    /// Blocking next; `None` when the planned stream is exhausted.
    pub fn next(&mut self) -> Option<Batch> {
        self.rx.recv().ok()
    }
}

impl Drop for Loader {
    fn drop(&mut self) {
        // Draining is unnecessary: sender exits on send error once rx drops.
        if let Some(h) = self.handle.take() {
            let _ = h;
            // detach: the worker exits as soon as it observes the closed channel
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};

    fn ds() -> Dataset {
        generate(&SynthSpec {
            name: "t",
            n_train: 100,
            n_val: 10,
            n_test: 10,
            d: 4,
            classes: 2,
            clusters_per_class: 1,
            redundancy: 0.5,
            label_noise: 0.0,
            margin: 2.0,
            easy_sigma: 0.3,
            hard_sigma: 1.0,
            seed: 5,
        })
        .train
    }

    #[test]
    fn yields_exact_count_and_shapes() {
        let d = ds();
        let mut l = Loader::spawn(&d, 16, 10, 1, 2);
        let mut count = 0;
        while let Some(b) = l.next() {
            assert_eq!(b.idx.len(), 16);
            assert_eq!(b.x.rows, 16);
            assert_eq!(b.y.len(), 16);
            count += 1;
        }
        assert_eq!(count, 10);
    }

    #[test]
    fn epoch_covers_all_examples_without_replacement() {
        let d = ds();
        // 100 examples / batch 20 -> 5 batches per epoch
        let mut l = Loader::spawn(&d, 20, 5, 2, 2);
        let mut seen = std::collections::HashSet::new();
        while let Some(b) = l.next() {
            for i in b.idx {
                assert!(seen.insert(i), "duplicate {i} within epoch");
            }
        }
        assert_eq!(seen.len(), 100);
    }

    #[test]
    fn reshuffles_between_epochs() {
        let d = ds();
        let mut l = Loader::spawn(&d, 100, 2, 3, 2);
        let a = l.next().unwrap().idx;
        let b = l.next().unwrap().idx;
        assert_ne!(a, b, "two epochs should have different order");
    }

    #[test]
    fn batch_content_matches_dataset() {
        let d = ds();
        let mut l = Loader::spawn(&d, 8, 1, 4, 1);
        let b = l.next().unwrap();
        for (k, &i) in b.idx.iter().enumerate() {
            assert_eq!(b.x.row(k), d.x.row(i));
            assert_eq!(b.y[k], d.y[i]);
        }
    }

    #[test]
    fn early_drop_does_not_hang() {
        let d = ds();
        let l = Loader::spawn(&d, 16, 1000, 5, 1);
        drop(l); // worker must exit via send error
    }
}
