//! Labeled dataset as a thin handle over a pluggable feature store.
//!
//! Features live behind [`DataStore`] — RAM-resident ([`MemStore`]) or
//! memory-mapped shards ([`super::store::MmapStore`]) — so the ground set
//! can exceed host memory. Labels and the per-example provenance metadata
//! (`difficulty`, `is_noisy`, `cluster`) stay resident: they are O(n)
//! bytes, not O(n·d), and the analysis benches (Fig. 5/7) index them at
//! random. Provenance is never visible to the training path.
//!
//! All feature access goes through [`Dataset::batch`],
//! [`Dataset::gather_into`] and [`Dataset::read_block`]; nothing above
//! this layer may assume a resident `x.data`. `Clone` is shallow (the
//! store is behind an `Arc`), which is what makes handing a dataset to
//! the prefetching loader thread cheap.

use std::sync::Arc;

use crate::data::store::{DataStore, MemStore};
use crate::tensor::MatF32;

/// A labeled dataset plus synthesis provenance, backed by a [`DataStore`].
#[derive(Debug, Clone)]
pub struct Dataset {
    store: Arc<dyn DataStore>,
    /// Integer class labels.
    pub y: Vec<i32>,
    /// Number of classes.
    pub classes: usize,
    /// Ground-truth difficulty in [0, 1] (0 = easiest): distance of the
    /// example from its cluster center relative to class margin.
    pub difficulty: Vec<f32>,
    /// Whether the label was corrupted by synthesis noise.
    pub is_noisy: Vec<bool>,
    /// Generating sub-cluster id (redundancy structure).
    pub cluster: Vec<u32>,
}

impl Dataset {
    /// Wrap an in-memory feature matrix (the historical representation).
    pub fn from_mat(
        x: MatF32,
        y: Vec<i32>,
        classes: usize,
        difficulty: Vec<f32>,
        is_noisy: Vec<bool>,
        cluster: Vec<u32>,
    ) -> Dataset {
        Dataset::with_store(Arc::new(MemStore::new(x)), y, classes, difficulty, is_noisy, cluster)
    }

    /// Wrap an arbitrary feature store. Metadata lengths must match `store.n()`.
    pub fn with_store(
        store: Arc<dyn DataStore>,
        y: Vec<i32>,
        classes: usize,
        difficulty: Vec<f32>,
        is_noisy: Vec<bool>,
        cluster: Vec<u32>,
    ) -> Dataset {
        let n = store.n();
        assert_eq!(y.len(), n, "labels/store length mismatch");
        assert_eq!(difficulty.len(), n, "difficulty/store length mismatch");
        assert_eq!(is_noisy.len(), n, "is_noisy/store length mismatch");
        assert_eq!(cluster.len(), n, "cluster/store length mismatch");
        Dataset { store, y, classes, difficulty, is_noisy, cluster }
    }

    /// Number of examples.
    pub fn n(&self) -> usize {
        self.store.n()
    }

    /// Feature dimensionality.
    pub fn d(&self) -> usize {
        self.store.d()
    }

    /// Which store backs the features (`"mem"` or `"mmap"`).
    pub fn store_kind(&self) -> &'static str {
        self.store.kind()
    }

    /// Read `rows` consecutive feature rows starting at `start` into `out`
    /// (length `rows * d`) — the block-at-a-time access path.
    pub fn read_block(&self, start: usize, rows: usize, out: &mut [f32]) {
        self.store.read_rows(start, rows, out);
    }

    /// Gather the feature rows for `idx` into a caller-provided matrix
    /// (shape `idx.len() × d`), allocating nothing. Pair with a
    /// [`crate::kernel::Workspace`] buffer for zero-allocation staging.
    pub fn gather_into(&self, idx: &[usize], x: &mut MatF32) {
        assert_eq!(x.rows, idx.len(), "gather_into: row count mismatch");
        assert_eq!(x.cols, self.d(), "gather_into: width mismatch");
        self.store.gather_into(idx, &mut x.data);
    }

    /// Gather a sub-dataset by example indices. The result is always
    /// RAM-resident (subsets are small working sets: coresets, eval
    /// slices), regardless of the parent's store.
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let (x, y) = self.batch(idx);
        Dataset::from_mat(
            x,
            y,
            self.classes,
            idx.iter().map(|&i| self.difficulty[i]).collect(),
            idx.iter().map(|&i| self.is_noisy[i]).collect(),
            idx.iter().map(|&i| self.cluster[i]).collect(),
        )
    }

    /// (features, labels) for the given indices — batch assembly.
    pub fn batch(&self, idx: &[usize]) -> (MatF32, Vec<i32>) {
        let mut x = MatF32::zeros(idx.len(), self.d());
        self.store.gather_into(idx, &mut x.data);
        (x, idx.iter().map(|&i| self.y[i]).collect())
    }

    /// Materialize all features as one resident matrix. Intended for
    /// tests, the monolithic cache writer and small analysis paths — do
    /// not call on corpora that only fit via the mmap store.
    pub fn to_mat(&self) -> MatF32 {
        let mut x = MatF32::zeros(self.n(), self.d());
        self.store.read_rows(0, self.n(), &mut x.data);
        x
    }

    /// Class histogram.
    pub fn class_counts(&self) -> Vec<usize> {
        let mut c = vec![0usize; self.classes];
        for &y in &self.y {
            c[y as usize] += 1;
        }
        c
    }
}

/// Train/validation/test partition of one generated corpus.
#[derive(Debug, Clone)]
pub struct Splits {
    /// Training split.
    pub train: Dataset,
    /// Validation split (GLISTER's reference set).
    pub val: Dataset,
    /// Held-out test split.
    pub test: Dataset,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset::from_mat(
            MatF32::from_vec(4, 2, vec![0., 0., 1., 1., 2., 2., 3., 3.]).unwrap(),
            vec![0, 1, 0, 1],
            2,
            vec![0.1, 0.2, 0.3, 0.4],
            vec![false, true, false, false],
            vec![0, 1, 0, 1],
        )
    }

    #[test]
    fn subset_preserves_metadata() {
        let d = tiny().subset(&[2, 0]);
        assert_eq!(d.n(), 2);
        assert_eq!(d.y, vec![0, 0]);
        assert_eq!(d.difficulty, vec![0.3, 0.1]);
        assert_eq!(d.cluster, vec![0, 0]);
        assert_eq!(d.store_kind(), "mem");
    }

    #[test]
    fn batch_gathers() {
        let (x, y) = tiny().batch(&[1, 3]);
        assert_eq!(x.data, vec![1., 1., 3., 3.]);
        assert_eq!(y, vec![1, 1]);
    }

    #[test]
    fn gather_into_matches_batch() {
        let d = tiny();
        let idx = [3, 0, 2];
        let (want, _) = d.batch(&idx);
        let mut got = MatF32::zeros(3, 2);
        d.gather_into(&idx, &mut got);
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn read_block_and_to_mat_agree() {
        let d = tiny();
        let mut block = vec![0.0f32; 2 * 2];
        d.read_block(1, 2, &mut block);
        assert_eq!(block, vec![1., 1., 2., 2.]);
        assert_eq!(d.to_mat().data, vec![0., 0., 1., 1., 2., 2., 3., 3.]);
    }

    #[test]
    fn class_counts() {
        assert_eq!(tiny().class_counts(), vec![2, 2]);
    }
}
