//! In-memory labeled dataset with per-example provenance metadata.
//!
//! The provenance fields (`difficulty`, `is_noisy`, `cluster`) exist so the
//! analysis benches (Fig. 5/7) can relate what CREST selects to ground-truth
//! example structure — they are never visible to the training path.

use crate::tensor::MatF32;

/// A labeled dataset plus synthesis provenance.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Features, one row per example.
    pub x: MatF32,
    /// Integer class labels.
    pub y: Vec<i32>,
    /// Number of classes.
    pub classes: usize,
    /// Ground-truth difficulty in [0, 1] (0 = easiest): distance of the
    /// example from its cluster center relative to class margin.
    pub difficulty: Vec<f32>,
    /// Whether the label was corrupted by synthesis noise.
    pub is_noisy: Vec<bool>,
    /// Generating sub-cluster id (redundancy structure).
    pub cluster: Vec<u32>,
}

impl Dataset {
    /// Number of examples.
    pub fn n(&self) -> usize {
        self.x.rows
    }

    /// Feature dimensionality.
    pub fn d(&self) -> usize {
        self.x.cols
    }

    /// Gather a sub-dataset by example indices.
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        Dataset {
            x: self.x.gather_rows(idx),
            y: idx.iter().map(|&i| self.y[i]).collect(),
            classes: self.classes,
            difficulty: idx.iter().map(|&i| self.difficulty[i]).collect(),
            is_noisy: idx.iter().map(|&i| self.is_noisy[i]).collect(),
            cluster: idx.iter().map(|&i| self.cluster[i]).collect(),
        }
    }

    /// (features, labels) for the given indices — batch assembly.
    pub fn batch(&self, idx: &[usize]) -> (MatF32, Vec<i32>) {
        (self.x.gather_rows(idx), idx.iter().map(|&i| self.y[i]).collect())
    }

    /// Class histogram.
    pub fn class_counts(&self) -> Vec<usize> {
        let mut c = vec![0usize; self.classes];
        for &y in &self.y {
            c[y as usize] += 1;
        }
        c
    }
}

/// Train/validation/test partition of one generated corpus.
#[derive(Debug, Clone)]
pub struct Splits {
    /// Training split.
    pub train: Dataset,
    /// Validation split (GLISTER's reference set).
    pub val: Dataset,
    /// Held-out test split.
    pub test: Dataset,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            x: MatF32::from_vec(4, 2, vec![0., 0., 1., 1., 2., 2., 3., 3.]).unwrap(),
            y: vec![0, 1, 0, 1],
            classes: 2,
            difficulty: vec![0.1, 0.2, 0.3, 0.4],
            is_noisy: vec![false, true, false, false],
            cluster: vec![0, 1, 0, 1],
        }
    }

    #[test]
    fn subset_preserves_metadata() {
        let d = tiny().subset(&[2, 0]);
        assert_eq!(d.n(), 2);
        assert_eq!(d.y, vec![0, 0]);
        assert_eq!(d.difficulty, vec![0.3, 0.1]);
        assert_eq!(d.cluster, vec![0, 0]);
    }

    #[test]
    fn batch_gathers() {
        let (x, y) = tiny().batch(&[1, 3]);
        assert_eq!(x.data, vec![1., 1., 3., 3.]);
        assert_eq!(y, vec![1, 1]);
    }

    #[test]
    fn class_counts() {
        assert_eq!(tiny().class_counts(), vec![2, 2]);
    }
}
