//! Forgettability scores (Toneva et al. 2018) — paper Fig. 5 / Fig. 7b.
//!
//! A *forgetting event* is a transition correct → incorrect between two
//! consecutive observations of the same example. The score of an example is
//! its forgetting-event count; never-learned examples are conventionally
//! assigned the maximum score (they are the hardest).

/// Per-example correctness trajectory statistics.
#[derive(Debug, Clone)]
pub struct ForgetTracker {
    /// last observed correctness (None = never observed)
    prev: Vec<Option<bool>>,
    forget_count: Vec<u32>,
    ever_correct: Vec<bool>,
    /// how many times each example appeared in a training batch (Fig. 7b)
    selection_count: Vec<u32>,
}

impl ForgetTracker {
    /// Tracker over `n` examples, all unobserved.
    pub fn new(n: usize) -> Self {
        ForgetTracker {
            prev: vec![None; n],
            forget_count: vec![0; n],
            ever_correct: vec![false; n],
            selection_count: vec![0; n],
        }
    }

    /// Record a correctness observation for one example.
    pub fn observe(&mut self, idx: usize, correct: bool) {
        if correct {
            self.ever_correct[idx] = true;
        }
        if let Some(true) = self.prev[idx] {
            if !correct {
                self.forget_count[idx] += 1;
            }
        }
        self.prev[idx] = Some(correct);
    }

    /// Record correctness observations (0/1 floats) for a batch.
    pub fn observe_batch(&mut self, idx: &[usize], correct: &[f32]) {
        debug_assert_eq!(idx.len(), correct.len());
        for (&i, &c) in idx.iter().zip(correct) {
            self.observe(i, c >= 0.5);
        }
    }

    /// Count a training-batch appearance (selection frequency, Fig. 7b).
    pub fn count_selection(&mut self, idx: &[usize]) {
        for &i in idx {
            self.selection_count[i] += 1;
        }
    }

    /// Forgettability score; never-learned examples get `max_score`.
    pub fn score(&self, idx: usize, max_score: u32) -> u32 {
        if self.ever_correct[idx] {
            self.forget_count[idx]
        } else {
            max_score
        }
    }

    /// Mean score over a set of examples.
    pub fn mean_score(&self, idx: &[usize], max_score: u32) -> f32 {
        if idx.is_empty() {
            return 0.0;
        }
        idx.iter().map(|&i| self.score(i, max_score) as f64).sum::<f64>() as f32
            / idx.len() as f32
    }

    /// Per-example training-batch appearance counts.
    pub fn selection_counts(&self) -> &[u32] {
        &self.selection_count
    }

    /// Largest forgetting-event count observed over all examples.
    pub fn max_observed_score(&self) -> u32 {
        self.forget_count.iter().copied().max().unwrap_or(0)
    }

    /// Histogram of scores over all examples (bins 0..=max then overflow).
    pub fn score_histogram(&self, max_score: u32) -> Vec<usize> {
        let mut h = vec![0usize; (max_score + 1) as usize];
        for i in 0..self.prev.len() {
            let s = self.score(i, max_score).min(max_score) as usize;
            h[s] += 1;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_correct_to_incorrect_transitions() {
        let mut t = ForgetTracker::new(1);
        for &c in &[true, false, true, true, false, false, true] {
            t.observe(0, c);
        }
        // transitions: T->F at 1, T->F at 4 => 2 forgetting events
        assert_eq!(t.score(0, 99), 2);
    }

    #[test]
    fn never_learned_gets_max_score() {
        let mut t = ForgetTracker::new(2);
        t.observe(0, false);
        t.observe(0, false);
        t.observe(1, true);
        assert_eq!(t.score(0, 7), 7);
        assert_eq!(t.score(1, 7), 0);
    }

    #[test]
    fn unobserved_counts_as_never_learned() {
        let t = ForgetTracker::new(1);
        assert_eq!(t.score(0, 5), 5);
    }

    #[test]
    fn mean_score_over_subset() {
        let mut t = ForgetTracker::new(3);
        t.observe(0, true);
        t.observe(0, false); // score 1
        t.observe(1, true); // score 0
        // 2 unobserved -> max 4
        assert!((t.mean_score(&[0, 1, 2], 4) - (1.0 + 0.0 + 4.0) / 3.0).abs() < 1e-6);
        assert_eq!(t.mean_score(&[], 4), 0.0);
    }

    #[test]
    fn selection_counts_accumulate() {
        let mut t = ForgetTracker::new(4);
        t.count_selection(&[0, 1, 1]);
        t.count_selection(&[1]);
        assert_eq!(t.selection_counts(), &[1, 3, 0, 0]);
    }

    #[test]
    fn histogram_bins_and_overflow() {
        let mut t = ForgetTracker::new(3);
        // ex0: 1 forget; ex1: learned, 0 forgets; ex2: never learned
        t.observe(0, true);
        t.observe(0, false);
        t.observe(1, true);
        let h = t.score_histogram(2);
        assert_eq!(h, vec![1, 1, 1]); // scores 0,1,2(capped)
    }
}
