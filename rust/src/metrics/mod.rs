//! Measurement instrumentation: forgettability scores, gradient
//! bias/variance probes, relative-error bookkeeping.

pub mod forget;
pub mod gradprobe;

/// Paper's headline metric (Table 1): relative error of a coreset run
/// against the full-data run, in percent: `|acc_c − acc_f| / acc_c × 100`.
///
/// (The paper defines the denominator as the coreset accuracy; we follow
/// that definition exactly.)
pub fn relative_error_pct(acc_coreset: f32, acc_full: f32) -> f32 {
    if acc_coreset <= 0.0 {
        return 100.0;
    }
    (acc_coreset - acc_full).abs() / acc_coreset * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relative_error_matches_definition() {
        assert!((relative_error_pct(90.0, 92.1) - (2.1 / 90.0 * 100.0)).abs() < 1e-4);
        assert_eq!(relative_error_pct(0.0, 50.0), 100.0);
        assert_eq!(relative_error_pct(50.0, 50.0), 0.0);
    }
}
