//! Gradient bias/variance probes (paper Fig. 1c/1d, Fig. 6, Fig. 9).
//!
//! The probes measure, in full parameter space, how well a mini-batch
//! sampling scheme estimates the full training gradient:
//!
//! * bias     `‖E[g_mb] − ∇L‖`
//! * variance `E[‖g_mb − ∇L‖²]`
//!
//! Batch gradients come from the `train_step` computation run with zero
//! momentum and lr=0 (`Runtime::batch_gradient`), so probes share the exact
//! backend compute path training uses.

use anyhow::Result;

use crate::data::Dataset;
use crate::runtime::Runtime;
use crate::util::stats;

/// Summary of a sampling scheme's gradient quality.
#[derive(Debug, Clone, Copy)]
pub struct GradStats {
    /// ‖E[g] − ∇L‖
    pub bias: f64,
    /// E[‖g − ∇L‖²]
    pub variance: f64,
    /// ‖∇L‖ (for normalized reporting, Fig. 6b)
    pub full_norm: f64,
}

/// Full-data mean gradient in parameter space, computed in chunks of r via
/// the Hutchinson-probe computation (z = 0 ⇒ it returns just the mean grad).
pub fn full_gradient(rt: &Runtime, params: &[f32], ds: &Dataset) -> Result<Vec<f32>> {
    let r = rt.man.r;
    let n = ds.n();
    let z = vec![0.0f32; rt.man.p_dim];
    let mut acc = vec![0.0f64; rt.man.p_dim];
    let mut weight_total = 0.0f64;
    let mut start = 0;
    while start < n {
        let end = (start + r).min(n);
        let valid = end - start;
        // pad the tail chunk by wrapping (weights account for the overlap)
        let idx: Vec<usize> = (start..start + r).map(|i| i % n).collect();
        let (x, y) = ds.batch(&idx);
        let probe = rt.hess_probe(params, &x, &y, &z)?;
        let w = valid as f64 / r as f64; // fraction of the chunk that is new
        for (a, &g) in acc.iter_mut().zip(&probe.grad) {
            *a += w * g as f64;
        }
        weight_total += w;
        start = end;
    }
    Ok(acc.into_iter().map(|v| (v / weight_total) as f32).collect())
}

/// Gradient of one weighted mini-batch (gamma normalized to mean 1).
pub fn batch_gradient(
    rt: &Runtime,
    params: &[f32],
    ds: &Dataset,
    idx: &[usize],
    gamma: &[f32],
) -> Result<Vec<f32>> {
    let (x, y) = ds.batch(idx);
    rt.batch_gradient(params, &x, &y, gamma)
}

/// Estimate bias and variance of a batch sampler over `k` draws.
///
/// `sampler` returns (indices, gamma) for one mini-batch of size m.
pub fn bias_variance<F>(
    rt: &Runtime,
    params: &[f32],
    ds: &Dataset,
    full_grad: &[f32],
    k: usize,
    mut sampler: F,
) -> Result<GradStats>
where
    F: FnMut() -> (Vec<usize>, Vec<f32>),
{
    let p = full_grad.len();
    let mut mean = vec![0.0f64; p];
    let mut var_acc = 0.0f64;
    for _ in 0..k {
        let (idx, gamma) = sampler();
        let g = batch_gradient(rt, params, ds, &idx, &gamma)?;
        let mut dev2 = 0.0f64;
        for j in 0..p {
            mean[j] += g[j] as f64 / k as f64;
            let d = g[j] as f64 - full_grad[j] as f64;
            dev2 += d * d;
        }
        var_acc += dev2 / k as f64;
    }
    let bias2: f64 = mean
        .iter()
        .zip(full_grad)
        .map(|(&m, &f)| (m - f as f64) * (m - f as f64))
        .sum();
    Ok(GradStats {
        bias: bias2.sqrt(),
        variance: var_acc,
        full_norm: stats::norm2(full_grad),
    })
}

/// Error of a single aggregate gradient estimate vs the full gradient
/// (Fig. 1b / Fig. 6a: coreset-union error).
pub fn gradient_error(estimate: &[f32], full: &[f32]) -> f64 {
    stats::norm2(&stats::sub(estimate, full))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gradient_error_is_euclidean() {
        let a = [1.0f32, 2.0, 2.0];
        let b = [0.0f32, 0.0, 0.0];
        assert!((gradient_error(&a, &b) - 3.0).abs() < 1e-9);
    }
}
