//! Run reports: everything a bench needs to print a paper table/figure row,
//! serializable to JSON for experiment bookkeeping.

use crate::util::json::Json;

/// One evaluation snapshot along training.
#[derive(Debug, Clone)]
pub struct EvalPoint {
    pub step: usize,
    pub backprops: u64,
    pub test_acc: f32,
    pub test_loss: f32,
    pub train_acc: f32,
    pub wall_secs: f64,
}

/// Outcome of one experiment run.
#[derive(Debug, Clone, Default)]
pub struct RunReport {
    pub method: String,
    pub variant: String,
    pub seed: u64,
    pub budget_frac: f32,
    pub final_test_acc: f32,
    pub final_test_loss: f32,
    pub best_test_acc: f32,
    pub steps: usize,
    pub backprops: u64,
    /// Selection rounds (coreset updates — Figs. 3/4).
    pub n_selection_updates: usize,
    pub selection_secs: f64,
    pub train_secs: f64,
    pub eval_secs: f64,
    /// ρ-check time (Table 2 "checking threshold").
    pub check_secs: f64,
    /// Quadratic-model construction time (Table 2 "loss approximation").
    pub approx_secs: f64,
    pub total_secs: f64,
    /// Examples excluded as learned (§4.3).
    pub n_excluded: usize,
    pub history: Vec<EvalPoint>,
    /// (step, ρ) at each check.
    pub rho_history: Vec<(usize, f32)>,
    /// (step, T₁) after each adaptation.
    pub t1_history: Vec<(usize, usize)>,
    /// Steps at which a selection update happened (Fig. 4 left).
    pub update_steps: Vec<usize>,
    /// (step, mean final forgettability of the examples selected there) —
    /// filled post-hoc by the coordinator (Fig. 5).
    pub forget_of_selected: Vec<(usize, f32)>,
    /// Per-example training-batch appearance counts (Fig. 7b).
    pub selection_counts: Vec<u32>,
    /// (step, accuracy of the currently-excluded examples) — Fig. 7a.
    pub dropped_acc_history: Vec<(usize, f32)>,
    /// Indices excluded as learned by the end of the run.
    pub excluded_indices: Vec<usize>,
    /// Mean per-step wall time of the training phase.
    pub mean_step_secs: f64,
    /// Mean per-selection wall time (Table 2 "selection").
    pub mean_selection_secs: f64,
}

impl RunReport {
    /// Wall-clock normalized to a reference run (Fig. 2 x-axis).
    pub fn normalized_runtime(&self, full_secs: f64) -> f64 {
        if full_secs <= 0.0 {
            return 0.0;
        }
        self.total_secs / full_secs
    }

    pub fn to_json(&self) -> Json {
        let history: Vec<Json> = self
            .history
            .iter()
            .map(|p| {
                Json::obj()
                    .set("step", p.step)
                    .set("backprops", p.backprops)
                    .set("test_acc", p.test_acc)
                    .set("test_loss", p.test_loss)
                    .set("train_acc", p.train_acc)
                    .set("wall_secs", p.wall_secs)
            })
            .collect();
        let rho: Vec<Json> = self
            .rho_history
            .iter()
            .map(|&(s, r)| Json::Arr(vec![Json::Num(s as f64), Json::Num(r as f64)]))
            .collect();
        Json::obj()
            .set("method", self.method.as_str())
            .set("variant", self.variant.as_str())
            .set("seed", self.seed)
            .set("budget_frac", self.budget_frac)
            .set("final_test_acc", self.final_test_acc)
            .set("final_test_loss", self.final_test_loss)
            .set("best_test_acc", self.best_test_acc)
            .set("steps", self.steps)
            .set("backprops", self.backprops)
            .set("n_selection_updates", self.n_selection_updates)
            .set("selection_secs", self.selection_secs)
            .set("train_secs", self.train_secs)
            .set("eval_secs", self.eval_secs)
            .set("check_secs", self.check_secs)
            .set("approx_secs", self.approx_secs)
            .set("total_secs", self.total_secs)
            .set("n_excluded", self.n_excluded)
            .set("mean_step_secs", self.mean_step_secs)
            .set("mean_selection_secs", self.mean_selection_secs)
            .set("history", Json::Arr(history))
            .set("rho_history", Json::Arr(rho))
    }
}

/// Fixed-width markdown-ish table printer for bench outputs.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(r[c].len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for c in 0..ncol {
                s.push_str(&format!(" {:<w$} |", cells[c], w = widths[c]));
            }
            s.push('\n');
            s
        };
        let mut out = line(&self.headers);
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for r in &self.rows {
            out.push_str(&line(r));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_roundtrip() {
        let r = RunReport {
            method: "crest".into(),
            variant: "cifar10-proxy".into(),
            final_test_acc: 0.85,
            rho_history: vec![(10, 0.01), (20, 0.2)],
            history: vec![EvalPoint {
                step: 5,
                backprops: 160,
                test_acc: 0.5,
                test_loss: 1.2,
                train_acc: 0.55,
                wall_secs: 0.1,
            }],
            ..Default::default()
        };
        let j = r.to_json();
        let parsed = crate::util::json::Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed.get("method").unwrap().as_str().unwrap(), "crest");
        assert_eq!(parsed.get("history").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(parsed.get("rho_history").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn normalized_runtime() {
        let r = RunReport { total_secs: 2.0, ..Default::default() };
        assert_eq!(r.normalized_runtime(4.0), 0.5);
        assert_eq!(r.normalized_runtime(0.0), 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["method", "acc"]);
        t.row(&["crest".to_string(), "85.0".to_string()]);
        t.row(&["craig-long-name".to_string(), "7".to_string()]);
        let s = t.render();
        assert!(s.contains("| method"));
        assert!(s.lines().count() == 4);
        let lens: Vec<usize> = s.lines().map(|l| l.len()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "aligned columns");
    }

    #[test]
    #[should_panic]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["x".to_string()]);
    }
}
