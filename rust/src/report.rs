//! Run reports: everything a bench needs to print a paper table/figure row,
//! serializable to JSON for experiment bookkeeping, plus the mean±std
//! aggregate rows the sweep orchestrator emits (Table-1/2 shape).

use anyhow::{bail, Result};

use crate::util::json::Json;

/// One evaluation snapshot along training.
#[derive(Debug, Clone)]
pub struct EvalPoint {
    /// Training step of the snapshot.
    pub step: usize,
    /// Cumulative backprops charged to the budget at this point.
    pub backprops: u64,
    /// Test-set accuracy.
    pub test_acc: f32,
    /// Mean test-set loss.
    pub test_loss: f32,
    /// Training-set accuracy.
    pub train_acc: f32,
    /// Wall-clock seconds since the run started.
    pub wall_secs: f64,
}

/// Outcome of one experiment run.
#[derive(Debug, Clone, Default)]
pub struct RunReport {
    /// Canonical method name (`api::Method::name`).
    pub method: String,
    /// Model/dataset variant the cell ran on.
    pub variant: String,
    /// Experiment seed.
    pub seed: u64,
    /// Training budget as a fraction of the full run's backprops.
    pub budget_frac: f32,
    /// Test accuracy at budget exhaustion.
    pub final_test_acc: f32,
    /// Mean test loss at budget exhaustion.
    pub final_test_loss: f32,
    /// Best test accuracy seen at any evaluation point.
    pub best_test_acc: f32,
    /// Training steps taken.
    pub steps: usize,
    /// Backprops actually charged to the budget.
    pub backprops: u64,
    /// Selection rounds (coreset updates — Figs. 3/4).
    pub n_selection_updates: usize,
    /// Total wall-clock spent selecting coresets.
    pub selection_secs: f64,
    /// Total wall-clock spent in training steps.
    pub train_secs: f64,
    /// Total wall-clock spent evaluating.
    pub eval_secs: f64,
    /// ρ-check time (Table 2 "checking threshold").
    pub check_secs: f64,
    /// Quadratic-model construction time (Table 2 "loss approximation").
    pub approx_secs: f64,
    /// End-to-end wall-clock of the run.
    pub total_secs: f64,
    /// Examples excluded as learned (§4.3).
    pub n_excluded: usize,
    /// Evaluation snapshots along training (Fig. 2 curves).
    pub history: Vec<EvalPoint>,
    /// (step, ρ) at each check.
    pub rho_history: Vec<(usize, f32)>,
    /// (step, T₁) after each adaptation.
    pub t1_history: Vec<(usize, usize)>,
    /// Steps at which a selection update happened (Fig. 4 left).
    pub update_steps: Vec<usize>,
    /// (step, mean final forgettability of the examples selected there) —
    /// filled post-hoc by the coordinator (Fig. 5).
    pub forget_of_selected: Vec<(usize, f32)>,
    /// Per-example training-batch appearance counts (Fig. 7b).
    pub selection_counts: Vec<u32>,
    /// (step, accuracy of the currently-excluded examples) — Fig. 7a.
    pub dropped_acc_history: Vec<(usize, f32)>,
    /// Indices excluded as learned by the end of the run.
    pub excluded_indices: Vec<usize>,
    /// Mean per-step wall time of the training phase.
    pub mean_step_secs: f64,
    /// Mean per-selection wall time (Table 2 "selection").
    pub mean_selection_secs: f64,
}

impl RunReport {
    /// Wall-clock normalized to a reference run (Fig. 2 x-axis).
    pub fn normalized_runtime(&self, full_secs: f64) -> f64 {
        if full_secs <= 0.0 {
            return 0.0;
        }
        self.total_secs / full_secs
    }

    /// Serialize for experiment bookkeeping (run files, sweep
    /// checkpoints). The figure-series vectors that only post-hoc analyses
    /// read (`t1_history`, `update_steps`, `forget_of_selected`,
    /// `selection_counts`, `dropped_acc_history`, `excluded_indices`) are
    /// not emitted; [`RunReport::from_json`] restores them as empty.
    pub fn to_json(&self) -> Json {
        let history: Vec<Json> = self
            .history
            .iter()
            .map(|p| {
                Json::obj()
                    .set("step", p.step)
                    .set("backprops", p.backprops)
                    .set("test_acc", p.test_acc)
                    .set("test_loss", p.test_loss)
                    .set("train_acc", p.train_acc)
                    .set("wall_secs", p.wall_secs)
            })
            .collect();
        let rho: Vec<Json> = self
            .rho_history
            .iter()
            .map(|&(s, r)| Json::Arr(vec![Json::Num(s as f64), Json::Num(r as f64)]))
            .collect();
        Json::obj()
            .set("method", self.method.as_str())
            .set("variant", self.variant.as_str())
            .set("seed", self.seed)
            .set("budget_frac", self.budget_frac)
            .set("final_test_acc", self.final_test_acc)
            .set("final_test_loss", self.final_test_loss)
            .set("best_test_acc", self.best_test_acc)
            .set("steps", self.steps)
            .set("backprops", self.backprops)
            .set("n_selection_updates", self.n_selection_updates)
            .set("selection_secs", self.selection_secs)
            .set("train_secs", self.train_secs)
            .set("eval_secs", self.eval_secs)
            .set("check_secs", self.check_secs)
            .set("approx_secs", self.approx_secs)
            .set("total_secs", self.total_secs)
            .set("n_excluded", self.n_excluded)
            .set("mean_step_secs", self.mean_step_secs)
            .set("mean_selection_secs", self.mean_selection_secs)
            .set("history", Json::Arr(history))
            .set("rho_history", Json::Arr(rho))
    }

    /// Parse a report serialized by [`RunReport::to_json`]. Fields that
    /// `to_json` does not emit default to empty — the deterministic core
    /// and all timing totals round-trip exactly. Float fields tolerate
    /// `null` (how the JSON writer encodes non-finite values) by reading
    /// it back as NaN, so a diverged run's checkpoint still restores.
    pub fn from_json(j: &Json) -> Result<RunReport> {
        // float field: a number, or null for a non-finite value
        fn num(j: &Json, key: &str) -> Result<f64> {
            match j.req(key)? {
                Json::Null => Ok(f64::NAN),
                v => v.as_f64(),
            }
        }
        let mut history = Vec::new();
        for p in j.req("history")?.as_arr()? {
            history.push(EvalPoint {
                step: p.req("step")?.as_usize()?,
                backprops: p.req("backprops")?.as_f64()? as u64,
                test_acc: num(p, "test_acc")? as f32,
                test_loss: num(p, "test_loss")? as f32,
                train_acc: num(p, "train_acc")? as f32,
                wall_secs: num(p, "wall_secs")?,
            });
        }
        let mut rho_history = Vec::new();
        for pair in j.req("rho_history")?.as_arr()? {
            let pair = pair.as_arr()?;
            if pair.len() != 2 {
                bail!("rho_history entries must be [step, rho] pairs");
            }
            let rho = match &pair[1] {
                Json::Null => f32::NAN,
                v => v.as_f64()? as f32,
            };
            rho_history.push((pair[0].as_usize()?, rho));
        }
        Ok(RunReport {
            method: j.req("method")?.as_str()?.to_string(),
            variant: j.req("variant")?.as_str()?.to_string(),
            seed: j.req("seed")?.as_f64()? as u64,
            budget_frac: num(j, "budget_frac")? as f32,
            final_test_acc: num(j, "final_test_acc")? as f32,
            final_test_loss: num(j, "final_test_loss")? as f32,
            best_test_acc: num(j, "best_test_acc")? as f32,
            steps: j.req("steps")?.as_usize()?,
            backprops: j.req("backprops")?.as_f64()? as u64,
            n_selection_updates: j.req("n_selection_updates")?.as_usize()?,
            selection_secs: num(j, "selection_secs")?,
            train_secs: num(j, "train_secs")?,
            eval_secs: num(j, "eval_secs")?,
            check_secs: num(j, "check_secs")?,
            approx_secs: num(j, "approx_secs")?,
            total_secs: num(j, "total_secs")?,
            n_excluded: j.req("n_excluded")?.as_usize()?,
            mean_step_secs: num(j, "mean_step_secs")?,
            mean_selection_secs: num(j, "mean_selection_secs")?,
            history,
            rho_history,
            ..Default::default()
        })
    }

    /// Canonical JSON of the deterministic fields only — accuracies,
    /// losses, counters, and the (step-indexed) histories, with every
    /// wall-clock field left out. Two runs of the same cell compare
    /// bitwise-equal through this view regardless of machine load, thread
    /// count, or whether one was restored from a checkpoint; the sweep
    /// resume tests assert exactly that.
    pub fn deterministic_json(&self) -> Json {
        let history: Vec<Json> = self
            .history
            .iter()
            .map(|p| {
                Json::obj()
                    .set("step", p.step)
                    .set("backprops", p.backprops)
                    .set("test_acc", p.test_acc)
                    .set("test_loss", p.test_loss)
                    .set("train_acc", p.train_acc)
            })
            .collect();
        let rho: Vec<Json> = self
            .rho_history
            .iter()
            .map(|&(s, r)| Json::Arr(vec![Json::Num(s as f64), Json::Num(r as f64)]))
            .collect();
        Json::obj()
            .set("method", self.method.as_str())
            .set("variant", self.variant.as_str())
            .set("seed", self.seed)
            .set("budget_frac", self.budget_frac)
            .set("final_test_acc", self.final_test_acc)
            .set("final_test_loss", self.final_test_loss)
            .set("best_test_acc", self.best_test_acc)
            .set("steps", self.steps)
            .set("backprops", self.backprops)
            .set("n_selection_updates", self.n_selection_updates)
            .set("n_excluded", self.n_excluded)
            .set("history", Json::Arr(history))
            .set("rho_history", Json::Arr(rho))
    }
}

/// One mean±std row of a sweep aggregate: all completed seeds of a
/// (variant, method, budget) group folded together — the row shape of the
/// paper's Tables 1 and 2. Only deterministic report fields are
/// aggregated, so identical cell sets render bitwise-identical rows.
#[derive(Debug, Clone)]
pub struct AggregateRow {
    /// Variant of the group.
    pub variant: String,
    /// Canonical method name of the group.
    pub method: String,
    /// Budget fraction of the group.
    pub budget_frac: f32,
    /// Number of seeds aggregated.
    pub n_seeds: usize,
    /// Mean final test accuracy (fraction, not percent).
    pub acc_mean: f32,
    /// Population std of the final test accuracy across seeds.
    pub acc_std: f32,
    /// Mean final test loss.
    pub loss_mean: f32,
    /// Mean relative error (%) vs the same-seed full-data run; `None`
    /// when the grid lacks a full reference for some seed of the group.
    pub rel_err_mean: Option<f32>,
    /// Population std of the relative error (%).
    pub rel_err_std: Option<f32>,
    /// Mean training steps.
    pub steps_mean: f32,
    /// Mean selection updates.
    pub updates_mean: f32,
    /// Mean examples excluded as learned.
    pub excluded_mean: f32,
}

impl AggregateRow {
    /// Trajectory record for `crest sweep --out`: a flat object identified
    /// by `name`, the same array-of-records shape `CREST_BENCH_JSON`
    /// uses, so sweep aggregates and perf records can share one file.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set(
                "name",
                format!("sweep/{}/{}/b{}", self.variant, self.method, self.budget_frac),
            )
            .set("variant", self.variant.as_str())
            .set("method", self.method.as_str())
            .set("budget_frac", self.budget_frac)
            .set("n_seeds", self.n_seeds)
            .set("acc_mean", self.acc_mean)
            .set("acc_std", self.acc_std)
            .set("loss_mean", self.loss_mean)
            .set("steps_mean", self.steps_mean)
            .set("updates_mean", self.updates_mean)
            .set("excluded_mean", self.excluded_mean);
        if let (Some(m), Some(s)) = (self.rel_err_mean, self.rel_err_std) {
            j = j.set("rel_err_mean", m).set("rel_err_std", s);
        }
        j
    }

    /// `mean±std` accuracy cell, paper-table style.
    pub fn fmt_acc(&self) -> String {
        format!("{:.4}±{:.4}", self.acc_mean, self.acc_std)
    }

    /// `mean±std` relative-error cell (percent), `-` without a reference.
    pub fn fmt_rel_err(&self) -> String {
        match (self.rel_err_mean, self.rel_err_std) {
            (Some(m), Some(s)) => format!("{m:.2}±{s:.1}"),
            _ => "-".to_string(),
        }
    }
}

/// Render aggregate rows as a markdown table — the `crest sweep` stdout
/// output. Deterministic for identical rows.
pub fn aggregate_markdown(rows: &[AggregateRow]) -> String {
    let mut t = Table::new(&[
        "variant",
        "method",
        "budget",
        "seeds",
        "test acc (mean±std)",
        "rel err %",
        "steps",
        "updates",
        "excluded",
    ]);
    for r in rows {
        t.row(&[
            r.variant.clone(),
            r.method.clone(),
            format!("{}", r.budget_frac),
            format!("{}", r.n_seeds),
            r.fmt_acc(),
            r.fmt_rel_err(),
            format!("{:.1}", r.steps_mean),
            format!("{:.1}", r.updates_mean),
            format!("{:.1}", r.excluded_mean),
        ]);
    }
    t.render()
}

/// Fixed-width markdown-ish table printer for bench outputs.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Table with the given column headers.
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append one row; panics when the arity differs from the headers.
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells.to_vec());
    }

    /// Render with columns padded to their widest cell.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(r[c].len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for c in 0..ncol {
                s.push_str(&format!(" {:<w$} |", cells[c], w = widths[c]));
            }
            s.push('\n');
            s
        };
        let mut out = line(&self.headers);
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for r in &self.rows {
            out.push_str(&line(r));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_roundtrip() {
        let r = RunReport {
            method: "crest".into(),
            variant: "cifar10-proxy".into(),
            final_test_acc: 0.85,
            rho_history: vec![(10, 0.01), (20, 0.2)],
            history: vec![EvalPoint {
                step: 5,
                backprops: 160,
                test_acc: 0.5,
                test_loss: 1.2,
                train_acc: 0.55,
                wall_secs: 0.1,
            }],
            ..Default::default()
        };
        let j = r.to_json();
        let parsed = crate::util::json::Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed.get("method").unwrap().as_str().unwrap(), "crest");
        assert_eq!(parsed.get("history").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(parsed.get("rho_history").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn report_from_json_roundtrips_deterministic_core_and_timings() {
        let r = RunReport {
            method: "crest".into(),
            variant: "smoke".into(),
            seed: 3,
            budget_frac: 0.1,
            final_test_acc: 0.8125,
            final_test_loss: 0.75,
            best_test_acc: 0.875,
            steps: 12,
            backprops: 192,
            n_selection_updates: 4,
            selection_secs: 0.5,
            train_secs: 1.5,
            eval_secs: 0.25,
            check_secs: 0.125,
            approx_secs: 0.0625,
            total_secs: 2.5,
            n_excluded: 3,
            mean_step_secs: 0.125,
            mean_selection_secs: 0.125,
            history: vec![EvalPoint {
                step: 5,
                backprops: 80,
                test_acc: 0.5,
                test_loss: 1.25,
                train_acc: 0.5625,
                wall_secs: 0.5,
            }],
            rho_history: vec![(4, 0.5), (8, 0.25)],
            ..Default::default()
        };
        let parsed =
            RunReport::from_json(&Json::parse(&r.to_json().to_string_pretty()).unwrap()).unwrap();
        // deterministic core is preserved bitwise
        assert_eq!(
            parsed.deterministic_json().to_string_pretty(),
            r.deterministic_json().to_string_pretty()
        );
        // timing totals survive too (they are just not part of the core)
        assert_eq!(parsed.total_secs, r.total_secs);
        assert_eq!(parsed.check_secs, r.check_secs);
        assert_eq!(parsed.history.len(), 1);
        assert_eq!(parsed.rho_history, r.rho_history);
        // deterministic view must not mention wall-clock fields
        let det = r.deterministic_json().to_string_pretty();
        assert!(!det.contains("secs"), "deterministic core leaked timing: {det}");
    }

    #[test]
    fn non_finite_metrics_survive_the_checkpoint_roundtrip() {
        // non-finite floats serialize as null; from_json reads them back
        // as NaN so a diverged run's checkpoint still restores
        let r = RunReport {
            method: "crest".into(),
            variant: "smoke".into(),
            final_test_loss: f32::NAN,
            rho_history: vec![(2, f32::INFINITY)],
            ..Default::default()
        };
        let parsed =
            RunReport::from_json(&Json::parse(&r.to_json().to_string_pretty()).unwrap()).unwrap();
        assert!(parsed.final_test_loss.is_nan());
        assert!(parsed.rho_history[0].1.is_nan(), "inf maps through null to NaN");
        // repeated roundtrips keep the deterministic core bitwise-stable
        let again =
            RunReport::from_json(&Json::parse(&parsed.to_json().to_string_pretty()).unwrap())
                .unwrap();
        assert_eq!(
            parsed.deterministic_json().to_string_pretty(),
            again.deterministic_json().to_string_pretty()
        );
    }

    #[test]
    fn aggregate_row_renders_and_serializes() {
        let row = AggregateRow {
            variant: "smoke".into(),
            method: "crest".into(),
            budget_frac: 0.1,
            n_seeds: 2,
            acc_mean: 0.65,
            acc_std: 0.05,
            loss_mean: 1.0,
            rel_err_mean: Some(12.5),
            rel_err_std: Some(2.5),
            steps_mean: 12.0,
            updates_mean: 4.0,
            excluded_mean: 1.5,
        };
        let j = row.to_json();
        assert_eq!(j.get("name").unwrap().as_str().unwrap(), "sweep/smoke/crest/b0.1");
        assert_eq!(j.get("n_seeds").unwrap().as_usize().unwrap(), 2);
        assert!(j.get("rel_err_mean").is_some());
        let md = aggregate_markdown(&[row.clone()]);
        assert!(md.contains("crest"));
        assert!(md.contains("0.6500±0.0500"));
        assert!(md.contains("12.50±2.5"));
        // missing reference renders as "-" and omits the JSON keys
        let no_ref = AggregateRow { rel_err_mean: None, rel_err_std: None, ..row };
        assert!(aggregate_markdown(&[no_ref.clone()]).contains(" - "));
        assert!(no_ref.to_json().get("rel_err_mean").is_none());
    }

    #[test]
    fn normalized_runtime() {
        let r = RunReport { total_secs: 2.0, ..Default::default() };
        assert_eq!(r.normalized_runtime(4.0), 0.5);
        assert_eq!(r.normalized_runtime(0.0), 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["method", "acc"]);
        t.row(&["crest".to_string(), "85.0".to_string()]);
        t.row(&["craig-long-name".to_string(), "7".to_string()]);
        let s = t.render();
        assert!(s.contains("| method"));
        assert!(s.lines().count() == 4);
        let lens: Vec<usize> = s.lines().map(|l| l.len()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "aligned columns");
    }

    #[test]
    #[should_panic]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["x".to_string()]);
    }
}
