//! Minimal, offline, API-compatible subset of the `anyhow` crate.
//!
//! The build environment has no crates.io registry, so this workspace
//! vendors exactly the surface the codebase uses: [`Error`], [`Result`],
//! the [`Context`] extension trait (on both `Result` and `Option`), and the
//! `anyhow!` / `bail!` / `ensure!` macros. Context frames are stored as a
//! message chain; `{:#}` formatting joins the chain with `": "` like the
//! real crate.

use std::fmt;

/// Error type: an outermost message plus the chain of underlying causes.
pub struct Error {
    /// `chain[0]` is the most recent context, `chain.last()` the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Construct from a displayable message (the `anyhow!` macro body).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an additional layer of context.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the message chain, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — full chain, outermost first, like anyhow
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with `Error` as the default
/// error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("root {}", 42)
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "root 42");
    }

    #[test]
    fn context_chains_and_alternate_format() {
        let e: Error = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root 42");
        assert_eq!(e.root_cause(), "root 42");
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let e = none.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
        let some = Some(7u32).with_context(|| "unused").unwrap();
        assert_eq!(some, 7);
    }

    #[test]
    fn std_error_conversion() {
        fn io() -> Result<()> {
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))?;
            Ok(())
        }
        let e = io().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(v: usize) -> Result<usize> {
            ensure!(v < 10, "too big: {v}");
            Ok(v)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(30).is_err());
    }
}
