//! Minimal, offline, API-compatible subset of the `log` facade.
//!
//! Provides the pieces `crest::util::logging` and the library's logging
//! call sites use: [`Level`], [`LevelFilter`], [`Log`], [`Record`],
//! [`Metadata`], `set_logger` / `set_max_level` / `max_level`, and the
//! `error!` … `trace!` macros (with implicit named captures via
//! `format_args!`).

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of a single log record.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Maximum-verbosity filter (adds `Off` below `Error`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata of a record: level + target (module path by default).
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus the formatted message arguments.
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// Sink for log records. Implementations must be `Send + Sync` so a single
/// static logger can serve every thread.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

struct NopLogger;

impl Log for NopLogger {
    fn enabled(&self, _: &Metadata) -> bool {
        false
    }

    fn log(&self, _: &Record) {}

    fn flush(&self) {}
}

static NOP: NopLogger = NopLogger;
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0);

/// Returned when `set_logger` is called twice.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("attempted to set a logger after one was already set")
    }
}

/// Install the global logger (first call wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum log level.
pub fn set_max_level(level: LevelFilter) {
    MAX_LEVEL.store(level as usize, Ordering::SeqCst);
}

/// The current global maximum log level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::SeqCst) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// The installed logger (a no-op sink before `set_logger`).
pub fn logger() -> &'static dyn Log {
    match LOGGER.get() {
        Some(l) => *l,
        None => &NOP,
    }
}

#[doc(hidden)]
pub fn __private_api_log(level: Level, target: &str, args: fmt::Arguments) {
    let record = Record { metadata: Metadata { level, target }, args };
    let logger = logger();
    if logger.enabled(&record.metadata) {
        logger.log(&record);
    }
}

/// Log at an explicit [`Level`].
#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {{
        let lvl = $lvl;
        if lvl <= $crate::max_level() {
            $crate::__private_api_log(lvl, module_path!(), format_args!($($arg)+));
        }
    }};
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Error, $($arg)+))
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Warn, $($arg)+))
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Info, $($arg)+))
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Debug, $($arg)+))
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Trace, $($arg)+))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filter_cross_comparisons() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(LevelFilter::Info >= Level::Info);
        assert!(Level::Trace == LevelFilter::Trace);
    }

    #[test]
    fn max_level_roundtrip_and_macros() {
        // single test: the level is global state, so splitting this across
        // tests would race under the parallel test runner
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Off);
        assert_eq!(max_level(), LevelFilter::Off);
        // with the filter off these must be cheap no-ops
        let x = 41;
        info!("value {x}");
        debug!("value {}", x + 1);
        error!("boom");
    }
}
